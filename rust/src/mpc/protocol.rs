//! End-to-end orchestration of one CMPC job (Algorithm 3).
//!
//! The serving-facing surface is [`crate::mpc::deployment::Deployment`]
//! (provision once, stream many jobs); this module holds the underlying
//! machinery it drives: setup (α assignment and the generalized-Vandermonde
//! solve for the `rₙ^{(i,l)}` coefficients), and per-job driving of the
//! **persistent** worker runtime — Phase-1 source sharing into pooled
//! payload buffers, a [`ControlMsg::JobStart`] hand-off to the long-lived
//! Phase-2 workers, and Phase-3 master reconstruction filtered by
//! [`JobId`] — then native verification of `Y = AᵀB` when asked.
//!
//! [`run_job`] submits one job against a live [`WorkerRuntime`]: it spawns
//! **zero threads** and performs zero fabric-payload allocations on a warm
//! runtime. [`run_protocol_with_env`] keeps the one-shot compatibility
//! shape by provisioning a throwaway runtime around a single job.
//!
//! Every entry point returns [`crate::error::Result`]; malformed inputs
//! surface as typed [`CmpcError`]s instead of panics, so one bad job cannot
//! take down a serving process.
//!
//! [`ControlMsg::JobStart`]: crate::mpc::network::ControlMsg::JobStart
//! [`JobId`]: crate::mpc::network::JobId

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::{CmpcScheme, SchemeParams};
use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::metrics::{PhaseTimings, TrafficReport, WorkerCounters};
use crate::mpc::chaos::ChaosPlan;
use crate::mpc::master::{MasterOutput, MasterTimings};
use crate::mpc::network::{ControlMsg, Payload};
use crate::mpc::runtime::WorkerRuntime;
use crate::mpc::{master, source};
use crate::poly::interp::choose_alphas;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::{BackendChoice, BackendFactory};
use crate::transport::shaper::LinkShaper;
use crate::util::rng::ChaChaRng;

/// Knobs for one protocol run.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    /// Matmul backend the workers compute `H = F_A·F_B` on.
    pub backend: BackendChoice,
    /// Seed for all secret randomness (sources and worker masks derive
    /// independent ChaCha streams from it).
    pub seed: u64,
    /// Check `Y == AᵀB` natively before returning.
    pub verify: bool,
    /// Per-worker injected compute delay (straggler model); empty = none.
    /// When non-empty, its length must equal the deployment's worker count.
    pub worker_delays: Vec<Duration>,
    /// Per-hop link latency.
    pub link_delay: Option<Duration>,
    /// Worker-pool size for the parallel sections (Phase-1 encoding,
    /// Phase-3 reconstruction, verify). `0` (the default) shares the
    /// process-wide pool at [`std::thread::available_parallelism`];
    /// `1` makes every parallel section literally sequential — the
    /// determinism tests compare `1` vs `N` byte-for-byte.
    pub threads: usize,
    /// Upper bound on any single fabric receive while a job is in flight,
    /// and the **per-job deadline** at each worker: a job with no traffic
    /// for this long fails with a typed [`CmpcError::Fabric`] — only that
    /// job; healthy concurrent jobs keep their own deadlines. It must
    /// comfortably exceed the longest legitimate compute + injected delay.
    pub recv_timeout: Duration,
    /// Decode as soon as any `t²+z` I-shares arrive and cancel the
    /// straggler tail with a `JobAbort` broadcast, instead of draining
    /// every worker's full remainder. Turns the code's redundancy into
    /// latency: a job stops depending on its slowest `N−(t²+z)` workers
    /// (and tolerates that many crashed ones). The overhead counters stay
    /// exact — each live aborted worker answers with an `AbortAck`
    /// carrying its final totals (drained within `recv_timeout`, metered
    /// as `PhaseTimings::ack_wait`). Off by default simply because the
    /// full drain generates no abort/ack traffic.
    pub early_decode: bool,
    /// Byzantine adversary tolerance `a` for this deployment's jobs: the
    /// master collects `t²+z+2a` I-shares and *locates* up to `a` garbled
    /// ones, excludes them (reconstruction stays byte-identical to a
    /// fault-free run) and reports them for eviction. The effective
    /// tolerance of a run is the max of this knob and the scheme's own
    /// [`SchemeParams::adversary_tolerance`] — set either. `0` (default)
    /// keeps the erasure-only decode.
    pub adversary_tolerance: usize,
    /// Consecutive per-job deadline-miss rounds after which a worker
    /// thread self-evicts for the runtime's reaper to replace. Rounds are
    /// consecutive only when **no envelope at all** arrives between them —
    /// any received traffic proves the link alive and resets the count; a
    /// worker that trips this is likely stuck behind a partitioned link.
    pub max_deadline_misses: usize,
    /// Optional deterministic fault-injection plan threaded through the
    /// fabric (see [`crate::mpc::chaos`]). `None` injects nothing.
    pub chaos: Option<Arc<ChaosPlan>>,
    /// Optional per-link latency/bandwidth emulation (see
    /// [`crate::transport::shaper`]). Unlike `link_delay` (which sleeps
    /// the sender), shaped envelopes are delayed *in flight* and the
    /// sender continues immediately — the honest model of a slow link.
    pub shaper: Option<Arc<LinkShaper>>,
}

impl Default for ProtocolConfig {
    fn default() -> ProtocolConfig {
        ProtocolConfig {
            backend: BackendChoice::Native,
            seed: 0xC0DE,
            verify: true,
            worker_delays: Vec::new(),
            link_delay: None,
            threads: 0,
            recv_timeout: Duration::from_secs(30),
            early_decode: false,
            adversary_tolerance: 0,
            max_deadline_misses: 8,
            chaos: None,
            shaper: None,
        }
    }
}

impl ProtocolConfig {
    /// Start a builder over the defaults.
    pub fn builder() -> ProtocolConfigBuilder {
        ProtocolConfigBuilder {
            config: ProtocolConfig::default(),
        }
    }
}

/// Builder for [`ProtocolConfig`].
#[derive(Clone, Debug, Default)]
pub struct ProtocolConfigBuilder {
    config: ProtocolConfig,
}

impl ProtocolConfigBuilder {
    /// Matmul backend for worker compute.
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.config.backend = backend;
        self
    }

    /// Seed for all secret randomness.
    pub fn seed(mut self, seed: u64) -> Self {
        self.config.seed = seed;
        self
    }

    /// Check `Y == AᵀB` natively before returning.
    pub fn verify(mut self, verify: bool) -> Self {
        self.config.verify = verify;
        self
    }

    /// Per-worker injected compute delays (straggler model).
    pub fn worker_delays(mut self, delays: Vec<Duration>) -> Self {
        self.config.worker_delays = delays;
        self
    }

    /// Per-hop link latency (sender sleeps).
    pub fn link_delay(mut self, delay: Option<Duration>) -> Self {
        self.config.link_delay = delay;
        self
    }

    /// Worker-pool size for the parallel sections (0 = all cores, shared).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    /// Per-job deadline for in-flight jobs (dead-worker detection).
    pub fn recv_timeout(mut self, timeout: Duration) -> Self {
        self.config.recv_timeout = timeout;
        self
    }

    /// Decode at the `t²+z` quota and cancel the straggler tail.
    pub fn early_decode(mut self, on: bool) -> Self {
        self.config.early_decode = on;
        self
    }

    /// Byzantine adversary tolerance `a` (locate and survive up to `a`
    /// garbled worker shares; raises the recovery quota to `t²+z+2a`).
    pub fn adversary_tolerance(mut self, a: usize) -> Self {
        self.config.adversary_tolerance = a;
        self
    }

    /// Consecutive deadline-miss rounds before a worker self-evicts.
    pub fn max_deadline_misses(mut self, rounds: usize) -> Self {
        self.config.max_deadline_misses = rounds;
        self
    }

    /// Attach a deterministic fault-injection plan to the deployment.
    pub fn chaos(mut self, plan: Arc<ChaosPlan>) -> Self {
        self.config.chaos = Some(plan);
        self
    }

    /// Attach per-link latency/bandwidth emulation to the deployment.
    pub fn shaper(mut self, shaper: Arc<LinkShaper>) -> Self {
        self.config.shaper = Some(shaper);
        self
    }

    /// Finish the builder.
    pub fn build(self) -> ProtocolConfig {
        self.config
    }
}

/// Everything a run reports back.
pub struct ProtocolOutput {
    /// The reconstructed product `Y = AᵀB`.
    pub y: FpMat,
    /// Name of the scheme that ran.
    pub scheme_name: String,
    /// Workers the deployment provisions.
    pub n_workers: usize,
    /// `N − quota`: how many stragglers this run could have survived.
    pub stragglers_tolerated: usize,
    /// Wall-clock phase breakdown.
    pub timings: PhaseTimings,
    /// This job's traffic only (concurrent jobs on a shared runtime meter
    /// independently; the fabric also keeps cumulative totals).
    pub traffic: TrafficReport,
    /// Per-worker overhead counters (index = worker id). **Final at
    /// return on both paths**: the full drain collects every worker's
    /// `JobDone` totals, and the early-decode fast path drains one
    /// `AbortAck` per live aborted worker (each acks only after dropping
    /// and tombstoning the job, so nothing can tick afterwards). The one
    /// exception is a worker that dies *during* the ack window — its
    /// counters stop with it.
    pub worker_counters: Vec<Arc<WorkerCounters>>,
    /// Whether the native `Y == AᵀB` check ran and passed (`false` when
    /// verification was disabled).
    pub verified: bool,
    /// Whether the master took the early-decode fast path (decoded at the
    /// recovery quota and cancelled a straggler tail).
    pub early_decoded: bool,
    /// Worker ids whose I-shares the Byzantine decoder located as garbled
    /// and excluded from reconstruction (sorted; empty when every share was
    /// consistent or `adversary_tolerance` is 0). The output `y` is already
    /// the corruption-free product — these indices are for blame/eviction.
    pub blamed_workers: Vec<usize>,
}

/// Precomputed per-deployment state reusable across jobs with the same
/// scheme and shape (the coordinator and [`Deployment`] cache this — the
/// O(N³) solve dominates setup).
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct Setup {
    /// Public evaluation points α₁..α_N (index = worker id).
    pub alphas: Arc<Vec<u64>>,
    /// `r_coeffs[n][i + t·l]` = worker n's combination coefficient for the
    /// important power (i,l) — eq. (18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    /// Workers the scheme provisions (`N`).
    pub n_workers: usize,
}

/// Build the α assignment and reconstruction coefficients for a scheme.
pub fn prepare_setup(scheme: &dyn CmpcScheme) -> Result<Setup> {
    let p = scheme.params();
    let n = scheme.n_workers();
    let needed = p.recovery_quota();
    if needed > n {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n,
        });
    }
    let support = scheme.reconstruction_support();
    let (alphas, inv_rows) = choose_alphas(n, &support)?;
    // Worker n needs r_n^{(i,l)} = inv_rows[row_of(imp(i,l))][n].
    let mut r_coeffs = vec![vec![0u64; p.t * p.t]; n];
    for i in 0..p.t {
        for l in 0..p.t {
            let e = scheme.important_power(i, l);
            let row = support.binary_search(&e).map_err(|_| {
                CmpcError::NotDecodable(format!(
                    "important power {e} missing from the reconstruction \
                     support of {}",
                    scheme.name()
                ))
            })?;
            for (wn, coeffs) in r_coeffs.iter_mut().enumerate() {
                coeffs[i + p.t * l] = inv_rows[row][wn];
            }
        }
    }
    Ok(Setup {
        alphas: Arc::new(alphas),
        r_coeffs: Arc::new(r_coeffs),
        n_workers: n,
    })
}

/// Check one job's matrices against each other and the scheme partition.
/// Shared by [`Deployment::execute`] and `Coordinator::submit` intake.
///
/// [`Deployment::execute`]: crate::mpc::deployment::Deployment::execute
pub fn validate_job_shapes(a: &FpMat, b: &FpMat, params: SchemeParams) -> Result<()> {
    if a.rows != a.cols || b.rows != b.cols || a.rows != b.rows {
        return Err(CmpcError::ShapeMismatch(format!(
            "inputs must be square matrices of equal size (got {}x{} and {}x{})",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let m = a.rows;
    if m == 0 {
        return Err(CmpcError::ShapeMismatch("inputs must be non-empty".to_string()));
    }
    if m % params.s != 0 || m % params.t != 0 {
        return Err(CmpcError::ShapeMismatch(format!(
            "partition (s={}, t={}) must divide m={m}",
            params.s, params.t
        )));
    }
    Ok(())
}

/// Everything a job run borrows from its deployment: the backend factory
/// (executor service + artifact cache), the worker pool driving the
/// parallel sections, and the per-pool-worker scratch buffers. A
/// [`Deployment`] owns all three for its lifetime, so steady-state jobs
/// reuse them; ad-hoc callers build them per run via
/// [`run_protocol_with_setup`].
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct ExecEnv<'a> {
    /// Shared (`Arc`) so the runtime can keep a handle for provisioning
    /// replacement workers on the eviction/respawn path.
    pub factory: &'a Arc<BackendFactory>,
    /// Worker pool driving the parallel sections.
    pub pool: &'a WorkerPool,
    /// Per-pool-worker scratch buffers.
    pub scratch: &'a ScratchPool,
}

/// Run one job against a prepared (possibly cached) [`Setup`], constructing
/// a fresh backend factory, pool, and scratch set from the config. Callers
/// issuing many jobs should build those once and use [`run_job`] against a
/// live runtime — or, at a higher level, a
/// [`crate::mpc::deployment::Deployment`].
pub fn run_protocol_with_setup(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
) -> Result<ProtocolOutput> {
    let factory = Arc::new(BackendFactory::new(&config.backend)?);
    let pool = WorkerPool::sized_or_global(config.threads);
    let scratch = ScratchPool::for_pool(&pool);
    run_protocol_with_env(
        scheme,
        setup,
        a,
        b,
        config,
        &ExecEnv {
            factory: &factory,
            pool: &pool,
            scratch: &scratch,
        },
    )
}

/// One-shot compatibility path: provision a throwaway [`WorkerRuntime`]
/// around a single job. Steady-state serving goes through a
/// [`crate::mpc::deployment::Deployment`], whose runtime (worker threads,
/// fabric, buffer pool) persists across jobs.
pub fn run_protocol_with_env(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
) -> Result<ProtocolOutput> {
    let runtime = WorkerRuntime::provision(setup, scheme.params(), config, env.factory)?;
    run_job(scheme, setup, a, b, config, env, &runtime)
    // runtime drops here: clean worker shutdown, panics propagated
}

/// Submit one job to a **live** worker runtime — the steady-state serving
/// path. The caller's thread plays the source and master roles; the
/// persistent worker threads run Phase 2. No threads are spawned, and all
/// fabric payloads ride pooled buffers.
pub fn run_job(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
    runtime: &WorkerRuntime,
) -> Result<ProtocolOutput> {
    let p = scheme.params();
    validate_job_shapes(a, b, p)?;
    let n = setup.n_workers;
    if runtime.n_workers() != n {
        return Err(CmpcError::InvalidParams(format!(
            "runtime provisions {} workers but the setup expects {n}",
            runtime.n_workers()
        )));
    }
    if !config.worker_delays.is_empty() && config.worker_delays.len() != n {
        return Err(CmpcError::InvalidParams(format!(
            "worker_delays has {} entries but the deployment provisions {n} \
             workers (leave empty for no injected delay)",
            config.worker_delays.len()
        )));
    }
    let job = runtime.begin_job();
    let result = drive_job(scheme, setup, a, b, config, env, runtime, job);
    if result.is_err() {
        // Tell every worker to drop the job: peers of a failed worker
        // would otherwise hold its JobState (waiting for a G-share that
        // never comes) until its per-job deadline fires — aborting frees
        // their state (and pooled buffers) immediately.
        let fabric = runtime.fabric();
        for wid in 0..n {
            let _ = fabric.send(
                job,
                fabric.master_id(),
                wid,
                Payload::Control(ControlMsg::JobAbort),
            );
        }
        runtime.note_job_aborted();
    }
    // Unregister whatever happened: late envelopes for the job are dropped
    // by the router (payload buffers return to the pool), the per-job
    // traffic meters are drained, and the buffer pool gets its high-water
    // trim opportunity.
    let traffic = runtime.finish_job(job);
    let (m_out, mt, counters, setup_time, phase1) = result?;
    // One Phase-3 decode happened (the counter contract in `metrics`).
    runtime.note_decode();
    if m_out.early_decoded {
        runtime.note_early_decode();
    }
    if !m_out.blamed_workers.is_empty() {
        // Located garbled shares: record the blame and evict the culprits
        // (the runtime shuts them down so the reaper respawns clean
        // replacements before the next job).
        runtime.note_byzantine(&m_out.blamed_workers);
    }

    let verified = if config.verify {
        // The reference product is the largest single matmul of the run
        // (full m×m·m); fan it across the pool.
        let mut at = FpMat::zeros(a.cols, a.rows);
        a.transpose_into(&mut at);
        let mut expect = FpMat::zeros(at.rows, b.cols);
        at.par_matmul_into(b, &mut expect, env.pool, env.scratch);
        m_out.y == expect
    } else {
        false
    };
    if config.verify && !verified {
        return Err(CmpcError::NotDecodable(format!(
            "reconstruction mismatch: Y != AᵀB under {}",
            scheme.name()
        )));
    }

    Ok(ProtocolOutput {
        y: m_out.y,
        scheme_name: scheme.name(),
        n_workers: n,
        stragglers_tolerated: m_out.stragglers_tolerated,
        timings: PhaseTimings {
            setup: setup_time,
            phase1_share: phase1,
            phase2_compute: mt.quota_wait + mt.tail_wait,
            phase3_reconstruct: mt.reconstruct,
            ack_wait: mt.ack_wait,
        },
        traffic,
        worker_counters: counters,
        verified,
        early_decoded: m_out.early_decoded,
        blamed_workers: m_out.blamed_workers,
    })
}

type DrivenJob = (
    MasterOutput,
    MasterTimings,
    Vec<Arc<WorkerCounters>>,
    Duration,
    Duration,
);

/// The fallible middle of [`run_job`]: announce the job, share, reconstruct.
/// Split out so `run_job` can unregister the job on every exit path.
#[allow(clippy::too_many_arguments)]
fn drive_job(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
    runtime: &WorkerRuntime,
    job: crate::mpc::network::JobId,
) -> Result<DrivenJob> {
    let p = scheme.params();
    let n = setup.n_workers;
    let fabric = runtime.fabric();

    // --- per-job secret streams (legacy fork order: source A, source B,
    // then workers 0..N — the persistent workers re-derive their own forks
    // from the same seed, so outputs stay byte-identical to the
    // spawn-per-job path) ---
    let t_setup = Instant::now();
    let mut job_rng = ChaChaRng::seed_from_u64(config.seed);
    let mut rng_src_a = job_rng.fork();
    let mut rng_src_b = job_rng.fork();
    let counters: Vec<Arc<WorkerCounters>> =
        (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
    for (wid, c) in counters.iter().enumerate() {
        fabric.send(
            job,
            fabric.master_id(),
            wid,
            Payload::Control(ControlMsg::JobStart {
                seed: config.seed,
                counters: c.clone(),
            }),
        )?;
    }
    let setup_time = t_setup.elapsed();

    // --- Phase 1: sources share (pooled payload buffers) ---
    let t1 = Instant::now();
    let fa_poly = source::build_f_a(scheme, a, &mut rng_src_a);
    let fb_poly = source::build_f_b(scheme, b, &mut rng_src_b);
    // Horner/power-table evaluation of both polynomials at every αₙ, fanned
    // out across the pool (§Perf P5).
    let shares = source::encode_shares_pooled(
        &fa_poly,
        &fb_poly,
        &setup.alphas,
        env.pool,
        env.scratch,
        runtime.buffers(),
    );
    for (wid, (fa_n, fb_n)) in shares.into_iter().enumerate() {
        // Source A evaluates F_A, source B evaluates F_B; one combined
        // envelope per worker keeps the fabric simple — traffic is metered
        // identically (both legs are source→worker).
        fabric.send(
            job,
            fabric.source_a_id(),
            wid,
            Payload::Shares { fa: fa_n, fb: fb_n },
        )?;
    }
    let phase1 = t1.elapsed();

    // --- Phase 2 runs on the persistent workers; Phase 3 here ---
    let (m_out, mt) = master::run_master(
        runtime.router(),
        fabric,
        job,
        &setup.alphas,
        n,
        p.t,
        p.z,
        config.adversary_tolerance.max(p.adversary_tolerance),
        config.recv_timeout,
        config.early_decode,
        &counters,
        env.pool,
        env.scratch,
    )?;
    Ok((m_out, mt, counters, setup_time, phase1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
    use crate::util::testing::property;

    /// One-shot run for tests: solve the setup, then run through the
    /// config-derived environment (what `Deployment` does once per session).
    fn run_once(
        scheme: &dyn CmpcScheme,
        a: &FpMat,
        b: &FpMat,
        config: &ProtocolConfig,
    ) -> Result<ProtocolOutput> {
        let setup = prepare_setup(scheme)?;
        run_protocol_with_setup(scheme, &setup, a, b, config)
    }

    fn run_scheme(scheme: &dyn CmpcScheme, m: usize, seed: u64) {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, a.transpose().matmul(&b));
    }

    #[test]
    fn age_example1_end_to_end() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        assert_eq!(scheme.n_workers(), 17);
        run_scheme(&scheme, 8, 1);
    }

    #[test]
    fn polydot_end_to_end() {
        run_scheme(&PolyDotCmpc::new(2, 2, 2), 8, 2);
        run_scheme(&PolyDotCmpc::new(3, 2, 4), 12, 3);
    }

    #[test]
    fn entangled_end_to_end() {
        run_scheme(&EntangledCmpc::new(2, 2, 2), 8, 4);
    }

    #[test]
    fn random_schemes_and_shapes_decode() {
        property("protocol decodes across (s,t,z,m)", 12, |rng| {
            let s = rng.gen_index(3) + 1;
            let t = rng.gen_index(3) + 1;
            let z = rng.gen_index(3) + 1;
            let m = (s * t) * (rng.gen_index(2) + 1) * 2;
            let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
            let a = FpMat::random(rng, m, m);
            let b = FpMat::random(rng, m, m);
            let cfg = ProtocolConfig::builder().seed(rng.next_u64()).build();
            let out = run_once(&scheme, &a, &b, &cfg)
                .map_err(|e| format!("s={s} t={t} z={z} m={m}: {e}"))?;
            if out.y != a.transpose().matmul(&b) {
                return Err(format!("wrong product at s={s} t={t} z={z} m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn straggler_tolerance_still_decodes() {
        // Delay two workers far beyond the rest; the master reconstructs
        // from the first t²+z arrivals regardless.
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N=17, needs 6
        let mut delays = vec![Duration::ZERO; 17];
        delays[0] = Duration::from_millis(150);
        delays[5] = Duration::from_millis(150);
        let cfg = ProtocolConfig::builder().worker_delays(delays).build();
        let mut rng = ChaChaRng::seed_from_u64(77);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let out = run_once(&scheme, &a, &b, &cfg).unwrap();
        assert!(out.verified);
        assert_eq!(out.stragglers_tolerated, 17 - 6);
    }

    #[test]
    fn traffic_matches_zeta_exactly() {
        // Measured worker↔worker scalars == ζ = N(N−1)·m²/t² (eq. 34).
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let (m, t) = (8usize, 2usize);
        let mut rng = ChaChaRng::seed_from_u64(13);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let zeta = crate::analysis::communication_overhead(m, t, n) as u64;
        assert_eq!(out.traffic.worker_to_worker, zeta);
    }

    #[test]
    fn worker_counters_match_xi_and_sigma() {
        // Measured per-worker multiplications == ξ (eq. 32) and stored
        // scalars == σ (eq. 33) — E10 in DESIGN.md.
        let (s, t, z, m) = (2usize, 2usize, 2usize, 8usize);
        let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
        let mut rng = ChaChaRng::seed_from_u64(21);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let xi = crate::analysis::computation_overhead(m, s, t, z, n) as u64;
        let sigma = crate::analysis::storage_overhead(m, s, t, z, n) as u64;
        for (wid, c) in out.worker_counters.iter().enumerate() {
            assert_eq!(c.mults(), xi, "ξ mismatch at worker {wid}");
            assert_eq!(c.stored(), sigma, "σ mismatch at worker {wid}");
        }
    }

    #[test]
    fn rejects_bad_partition() {
        let scheme = AgeCmpc::new(3, 2, 1, 0);
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 8, 8); // 3 ∤ 8
        let b = FpMat::random(&mut rng, 8, 8);
        let err = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap_err();
        assert!(matches!(err, CmpcError::ShapeMismatch(_)));
    }

    #[test]
    fn rejects_mismatched_worker_delays() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N = 17
        let mut rng = ChaChaRng::seed_from_u64(3);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let cfg = ProtocolConfig::builder()
            .worker_delays(vec![Duration::ZERO; 3])
            .build();
        let err = run_once(&scheme, &a, &b, &cfg).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }

    #[test]
    fn builder_round_trips_every_knob() {
        let cfg = ProtocolConfig::builder()
            .backend(BackendChoice::Native)
            .seed(99)
            .verify(false)
            .worker_delays(vec![Duration::from_millis(1); 2])
            .link_delay(Some(Duration::from_micros(5)))
            .threads(3)
            .recv_timeout(Duration::from_secs(2))
            .early_decode(true)
            .adversary_tolerance(2)
            .max_deadline_misses(3)
            .chaos(ChaosPlan::new().into_shared())
            .shaper(LinkShaper::new().into_shared())
            .build();
        assert_eq!(cfg.seed, 99);
        assert!(!cfg.verify);
        assert_eq!(cfg.worker_delays.len(), 2);
        assert_eq!(cfg.link_delay, Some(Duration::from_micros(5)));
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.recv_timeout, Duration::from_secs(2));
        assert!(cfg.early_decode);
        assert_eq!(cfg.adversary_tolerance, 2);
        assert_eq!(cfg.max_deadline_misses, 3);
        assert!(cfg.chaos.is_some());
        assert!(cfg.shaper.is_some());
    }

    #[test]
    fn early_decode_cancels_the_straggler_tail() {
        // Two workers whose *own* I-share leg straggles (the paper's
        // tolerated-dropout regime: their G-exchange contribution already
        // delivered). The early-decode path returns at the t²+z quota with
        // the identical (verified) product instead of waiting out the tail.
        // Measured on a live deployment so the runtime's own teardown
        // (which joins the still-sleeping stragglers) stays outside the
        // timed window.
        use crate::codes::SchemeParams;
        use crate::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
        use crate::mpc::deployment::Deployment;
        use crate::SchemeSpec;
        let delay = Duration::from_millis(150);
        let mut plan = ChaosPlan::new(); // AGE(2,2,2): N=17, quota 6
        for victim in [3usize, 11] {
            plan = plan.rule(
                FaultRule::new(FaultAction::Delay(delay))
                    .from_node(victim)
                    .class(PayloadClass::IShare),
            );
        }
        let mut rng = ChaChaRng::seed_from_u64(99);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let cfg = ProtocolConfig::builder()
            .early_decode(true)
            .chaos(plan.into_shared())
            .build();
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            SchemeParams::new(2, 2, 2),
            cfg,
        )
        .unwrap();
        let out = dep.execute(&a, &b).unwrap();
        assert!(out.verified);
        // The fast path fired: decoded at the quota with the stragglers'
        // acks outstanding (the *relative* latency win over the full-drain
        // path is asserted, with wall clocks, in tests/fault_tolerance.rs —
        // an absolute bound here would flake on loaded CI runners).
        assert!(out.early_decoded);
        assert_eq!(out.y, a.transpose().matmul(&b));
        assert!(out.timings.phase2_compute < delay, "tail was waited for");
        assert!(dep.runtime().health().early_decodes >= 1);
        // The abort-ack drain makes the fast path's counters final at
        // return (the ack window — not phase 2 — absorbs the sleeping
        // victims' wake-ups): nothing may tick afterwards.
        let snap: Vec<(u64, u64)> = out
            .worker_counters
            .iter()
            .map(|c| (c.mults(), c.stored()))
            .collect();
        std::thread::sleep(delay + Duration::from_millis(50));
        let after: Vec<(u64, u64)> = out
            .worker_counters
            .iter()
            .map(|c| (c.mults(), c.stored()))
            .collect();
        assert_eq!(snap, after, "counters ticked after an early-decoded return");
    }
}
