//! End-to-end orchestration of one CMPC job (Algorithm 3).
//!
//! The serving-facing surface is [`crate::mpc::deployment::Deployment`]
//! (provision once, execute many jobs); this module holds the underlying
//! machinery it drives: setup (α assignment and the generalized-Vandermonde
//! solve for the `rₙ^{(i,l)}` coefficients), Phase 1 source sharing, `N`
//! Phase-2 worker threads over the network fabric, and Phase-3 master
//! reconstruction — then native verification of `Y = AᵀB` when asked.
//!
//! Every entry point returns [`crate::error::Result`]; malformed inputs
//! surface as typed [`CmpcError`]s instead of panics, so one bad job cannot
//! take down a serving process.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::{CmpcScheme, SchemeParams};
use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::metrics::{PhaseTimings, TrafficReport, WorkerCounters};
use crate::mpc::network::{Fabric, Payload};
use crate::mpc::{master, source, worker};
use crate::poly::interp::choose_alphas;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::{BackendChoice, BackendFactory};
use crate::util::rng::ChaChaRng;

/// Knobs for one protocol run.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub backend: BackendChoice,
    /// Seed for all secret randomness (sources and worker masks derive
    /// independent ChaCha streams from it).
    pub seed: u64,
    /// Check `Y == AᵀB` natively before returning.
    pub verify: bool,
    /// Per-worker injected compute delay (straggler model); empty = none.
    /// When non-empty, its length must equal the deployment's worker count.
    pub worker_delays: Vec<Duration>,
    /// Per-hop link latency.
    pub link_delay: Option<Duration>,
    /// Worker-pool size for the parallel sections (Phase-1 encoding,
    /// Phase-3 reconstruction, verify). `0` (the default) shares the
    /// process-wide pool at [`std::thread::available_parallelism`];
    /// `1` makes every parallel section literally sequential — the
    /// determinism tests compare `1` vs `N` byte-for-byte.
    pub threads: usize,
}

impl Default for ProtocolConfig {
    fn default() -> ProtocolConfig {
        ProtocolConfig {
            backend: BackendChoice::Native,
            seed: 0xC0DE,
            verify: true,
            worker_delays: Vec::new(),
            link_delay: None,
            threads: 0,
        }
    }
}

impl ProtocolConfig {
    /// Start a builder over the defaults.
    pub fn builder() -> ProtocolConfigBuilder {
        ProtocolConfigBuilder {
            config: ProtocolConfig::default(),
        }
    }
}

/// Builder for [`ProtocolConfig`].
#[derive(Clone, Debug, Default)]
pub struct ProtocolConfigBuilder {
    config: ProtocolConfig,
}

impl ProtocolConfigBuilder {
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.config.backend = backend;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.config.seed = seed;
        self
    }

    pub fn verify(mut self, verify: bool) -> Self {
        self.config.verify = verify;
        self
    }

    pub fn worker_delays(mut self, delays: Vec<Duration>) -> Self {
        self.config.worker_delays = delays;
        self
    }

    pub fn link_delay(mut self, delay: Option<Duration>) -> Self {
        self.config.link_delay = delay;
        self
    }

    /// Worker-pool size for the parallel sections (0 = all cores, shared).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    pub fn build(self) -> ProtocolConfig {
        self.config
    }
}

/// Everything a run reports back.
pub struct ProtocolOutput {
    pub y: FpMat,
    pub scheme_name: String,
    pub n_workers: usize,
    pub stragglers_tolerated: usize,
    pub timings: PhaseTimings,
    pub traffic: TrafficReport,
    /// Per-worker overhead counters (index = worker id).
    pub worker_counters: Vec<Arc<WorkerCounters>>,
    pub verified: bool,
}

/// Precomputed per-deployment state reusable across jobs with the same
/// scheme and shape (the coordinator and [`Deployment`] cache this — the
/// O(N³) solve dominates setup).
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct Setup {
    pub alphas: Arc<Vec<u64>>,
    /// `r_coeffs[n][i + t·l]` = worker n's combination coefficient for the
    /// important power (i,l) — eq. (18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    pub n_workers: usize,
}

/// Build the α assignment and reconstruction coefficients for a scheme.
pub fn prepare_setup(scheme: &dyn CmpcScheme) -> Result<Setup> {
    let p = scheme.params();
    let n = scheme.n_workers();
    let needed = p.t * p.t + p.z;
    if needed > n {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n,
        });
    }
    let support = scheme.reconstruction_support();
    let (alphas, inv_rows) = choose_alphas(n, &support)?;
    // Worker n needs r_n^{(i,l)} = inv_rows[row_of(imp(i,l))][n].
    let mut r_coeffs = vec![vec![0u64; p.t * p.t]; n];
    for i in 0..p.t {
        for l in 0..p.t {
            let e = scheme.important_power(i, l);
            let row = support.binary_search(&e).map_err(|_| {
                CmpcError::NotDecodable(format!(
                    "important power {e} missing from the reconstruction \
                     support of {}",
                    scheme.name()
                ))
            })?;
            for (wn, coeffs) in r_coeffs.iter_mut().enumerate() {
                coeffs[i + p.t * l] = inv_rows[row][wn];
            }
        }
    }
    Ok(Setup {
        alphas: Arc::new(alphas),
        r_coeffs: Arc::new(r_coeffs),
        n_workers: n,
    })
}

/// Check one job's matrices against each other and the scheme partition.
/// Shared by [`Deployment::execute`] and `Coordinator::submit` intake.
///
/// [`Deployment::execute`]: crate::mpc::deployment::Deployment::execute
pub fn validate_job_shapes(a: &FpMat, b: &FpMat, params: SchemeParams) -> Result<()> {
    if a.rows != a.cols || b.rows != b.cols || a.rows != b.rows {
        return Err(CmpcError::ShapeMismatch(format!(
            "inputs must be square matrices of equal size (got {}x{} and {}x{})",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let m = a.rows;
    if m == 0 {
        return Err(CmpcError::ShapeMismatch("inputs must be non-empty".to_string()));
    }
    if m % params.s != 0 || m % params.t != 0 {
        return Err(CmpcError::ShapeMismatch(format!(
            "partition (s={}, t={}) must divide m={m}",
            params.s, params.t
        )));
    }
    Ok(())
}

/// Everything a job run borrows from its deployment: the backend factory
/// (executor service + artifact cache), the worker pool driving the
/// parallel sections, and the per-pool-worker scratch buffers. A
/// [`Deployment`] owns all three for its lifetime, so steady-state jobs
/// reuse them; ad-hoc callers build them per run via
/// [`run_protocol_with_setup`].
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct ExecEnv<'a> {
    pub factory: &'a BackendFactory,
    pub pool: &'a WorkerPool,
    pub scratch: &'a ScratchPool,
}

/// Run one job against a prepared (possibly cached) [`Setup`], constructing
/// a fresh backend factory, pool, and scratch set from the config. Callers
/// issuing many jobs should build those once and use
/// [`run_protocol_with_env`] — or, at a higher level, a
/// [`crate::mpc::deployment::Deployment`].
pub fn run_protocol_with_setup(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
) -> Result<ProtocolOutput> {
    let factory = BackendFactory::new(&config.backend)?;
    let pool = WorkerPool::sized_or_global(config.threads);
    let scratch = ScratchPool::for_pool(&pool);
    run_protocol_with_env(
        scheme,
        setup,
        a,
        b,
        config,
        &ExecEnv {
            factory: &factory,
            pool: &pool,
            scratch: &scratch,
        },
    )
}

/// Run one job with an existing execution environment (shared executor
/// service, worker pool, and scratch buffers across jobs — the steady-state
/// serving path).
pub fn run_protocol_with_env(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
) -> Result<ProtocolOutput> {
    let p = scheme.params();
    validate_job_shapes(a, b, p)?;
    let n = setup.n_workers;
    if !config.worker_delays.is_empty() && config.worker_delays.len() != n {
        return Err(CmpcError::InvalidParams(format!(
            "worker_delays has {} entries but the deployment provisions {n} \
             workers (leave empty for no injected delay)",
            config.worker_delays.len()
        )));
    }
    let t_setup = Instant::now();
    let mut job_rng = ChaChaRng::seed_from_u64(config.seed);
    let mut rng_src_a = job_rng.fork();
    let mut rng_src_b = job_rng.fork();
    let worker_rngs: Vec<ChaChaRng> = (0..n).map(|_| job_rng.fork()).collect();

    let (fabric, mut endpoints) = Fabric::new(n, config.link_delay);
    let counters: Vec<Arc<WorkerCounters>> =
        (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
    let setup_time = t_setup.elapsed();

    // --- spawn workers ---
    let mut worker_endpoints: Vec<_> = endpoints.drain(0..n).collect();
    let master_endpoint = endpoints.remove(0);
    let mut handles = Vec::with_capacity(n);
    for (wid, rng) in worker_rngs.into_iter().enumerate() {
        let ctx = worker::WorkerCtx {
            id: wid,
            n_workers: n,
            t: p.t,
            z: p.z,
            alphas: setup.alphas.clone(),
            r_coeffs: setup.r_coeffs.clone(),
            rng,
            counters: counters[wid].clone(),
            delay: config
                .worker_delays
                .get(wid)
                .copied()
                .unwrap_or(Duration::ZERO),
        };
        let endpoint = worker_endpoints.remove(0);
        let fabric = fabric.clone();
        let backend = env.factory.make();
        handles.push(
            std::thread::Builder::new()
                .name(format!("cmpc-worker-{wid}"))
                .spawn(move || worker::run_worker(ctx, endpoint, fabric, backend))
                .expect("spawn worker thread"),
        );
    }

    // --- Phase 1: sources share ---
    let t1 = Instant::now();
    let fa_poly = source::build_f_a(scheme, a, &mut rng_src_a);
    let fb_poly = source::build_f_b(scheme, b, &mut rng_src_b);
    // Horner/power-table evaluation of both polynomials at every αₙ, fanned
    // out across the pool (§Perf P5).
    let shares = source::encode_shares(&fa_poly, &fb_poly, &setup.alphas, env.pool, env.scratch);
    for (wid, (fa_n, fb_n)) in shares.into_iter().enumerate() {
        // Source A evaluates F_A, source B evaluates F_B; one combined
        // envelope per worker keeps the fabric simple — traffic is metered
        // identically (both legs are source→worker).
        fabric
            .send(fabric.source_a_id(), wid, Payload::Shares { fa: fa_n, fb: fb_n })
            .map_err(|_| CmpcError::Fabric(format!("worker {wid} unreachable in phase 1")))?;
    }
    let phase1 = t1.elapsed();

    // --- Phase 2/3 run concurrently; wait for the master ---
    let t2 = Instant::now();
    let m_out = master::run_master(
        &master_endpoint,
        &setup.alphas,
        n,
        p.t,
        p.z,
        env.pool,
        env.scratch,
    )?;
    let reconstruct_done = t2.elapsed();
    // Workers finish their sends after reconstruction; join them for clean
    // counter totals. Their tail time counts toward phase 2.
    for h in handles {
        h.join()
            .map_err(|_| CmpcError::Fabric("worker thread panicked".to_string()))??;
    }
    let all_done = t2.elapsed();

    let verified = if config.verify {
        // The reference product is the largest single matmul of the run
        // (full m×m·m); fan it across the pool.
        let mut at = FpMat::zeros(a.cols, a.rows);
        a.transpose_into(&mut at);
        let mut expect = FpMat::zeros(at.rows, b.cols);
        at.par_matmul_into(b, &mut expect, env.pool, env.scratch);
        m_out.y == expect
    } else {
        false
    };
    if config.verify && !verified {
        return Err(CmpcError::NotDecodable(format!(
            "reconstruction mismatch: Y != AᵀB under {}",
            scheme.name()
        )));
    }

    Ok(ProtocolOutput {
        y: m_out.y,
        scheme_name: scheme.name(),
        n_workers: n,
        stragglers_tolerated: m_out.stragglers_tolerated,
        timings: PhaseTimings {
            setup: setup_time,
            phase1_share: phase1,
            phase2_compute: all_done,
            phase3_reconstruct: all_done.saturating_sub(reconstruct_done),
        },
        traffic: fabric.traffic(),
        worker_counters: counters,
        verified,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
    use crate::util::testing::property;

    /// One-shot run for tests: solve the setup, then run through the
    /// config-derived environment (what `Deployment` does once per session).
    fn run_once(
        scheme: &dyn CmpcScheme,
        a: &FpMat,
        b: &FpMat,
        config: &ProtocolConfig,
    ) -> Result<ProtocolOutput> {
        let setup = prepare_setup(scheme)?;
        run_protocol_with_setup(scheme, &setup, a, b, config)
    }

    fn run_scheme(scheme: &dyn CmpcScheme, m: usize, seed: u64) {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, a.transpose().matmul(&b));
    }

    #[test]
    fn age_example1_end_to_end() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        assert_eq!(scheme.n_workers(), 17);
        run_scheme(&scheme, 8, 1);
    }

    #[test]
    fn polydot_end_to_end() {
        run_scheme(&PolyDotCmpc::new(2, 2, 2), 8, 2);
        run_scheme(&PolyDotCmpc::new(3, 2, 4), 12, 3);
    }

    #[test]
    fn entangled_end_to_end() {
        run_scheme(&EntangledCmpc::new(2, 2, 2), 8, 4);
    }

    #[test]
    fn random_schemes_and_shapes_decode() {
        property("protocol decodes across (s,t,z,m)", 12, |rng| {
            let s = rng.gen_index(3) + 1;
            let t = rng.gen_index(3) + 1;
            let z = rng.gen_index(3) + 1;
            let m = (s * t) * (rng.gen_index(2) + 1) * 2;
            let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
            let a = FpMat::random(rng, m, m);
            let b = FpMat::random(rng, m, m);
            let cfg = ProtocolConfig::builder().seed(rng.next_u64()).build();
            let out = run_once(&scheme, &a, &b, &cfg)
                .map_err(|e| format!("s={s} t={t} z={z} m={m}: {e}"))?;
            if out.y != a.transpose().matmul(&b) {
                return Err(format!("wrong product at s={s} t={t} z={z} m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn straggler_tolerance_still_decodes() {
        // Delay two workers far beyond the rest; the master reconstructs
        // from the first t²+z arrivals regardless.
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N=17, needs 6
        let mut delays = vec![Duration::ZERO; 17];
        delays[0] = Duration::from_millis(150);
        delays[5] = Duration::from_millis(150);
        let cfg = ProtocolConfig::builder().worker_delays(delays).build();
        let mut rng = ChaChaRng::seed_from_u64(77);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let out = run_once(&scheme, &a, &b, &cfg).unwrap();
        assert!(out.verified);
        assert_eq!(out.stragglers_tolerated, 17 - 6);
    }

    #[test]
    fn traffic_matches_zeta_exactly() {
        // Measured worker↔worker scalars == ζ = N(N−1)·m²/t² (eq. 34).
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let (m, t) = (8usize, 2usize);
        let mut rng = ChaChaRng::seed_from_u64(13);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let zeta = crate::analysis::communication_overhead(m, t, n) as u64;
        assert_eq!(out.traffic.worker_to_worker, zeta);
    }

    #[test]
    fn worker_counters_match_xi_and_sigma() {
        // Measured per-worker multiplications == ξ (eq. 32) and stored
        // scalars == σ (eq. 33) — E10 in DESIGN.md.
        let (s, t, z, m) = (2usize, 2usize, 2usize, 8usize);
        let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
        let mut rng = ChaChaRng::seed_from_u64(21);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let xi = crate::analysis::computation_overhead(m, s, t, z, n) as u64;
        let sigma = crate::analysis::storage_overhead(m, s, t, z, n) as u64;
        for (wid, c) in out.worker_counters.iter().enumerate() {
            assert_eq!(c.mults(), xi, "ξ mismatch at worker {wid}");
            assert_eq!(c.stored(), sigma, "σ mismatch at worker {wid}");
        }
    }

    #[test]
    fn rejects_bad_partition() {
        let scheme = AgeCmpc::new(3, 2, 1, 0);
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 8, 8); // 3 ∤ 8
        let b = FpMat::random(&mut rng, 8, 8);
        let err = run_once(&scheme, &a, &b, &ProtocolConfig::default()).unwrap_err();
        assert!(matches!(err, CmpcError::ShapeMismatch(_)));
    }

    #[test]
    fn rejects_mismatched_worker_delays() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N = 17
        let mut rng = ChaChaRng::seed_from_u64(3);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let cfg = ProtocolConfig::builder()
            .worker_delays(vec![Duration::ZERO; 3])
            .build();
        let err = run_once(&scheme, &a, &b, &cfg).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }

    #[test]
    fn builder_round_trips_every_knob() {
        let cfg = ProtocolConfig::builder()
            .backend(BackendChoice::Native)
            .seed(99)
            .verify(false)
            .worker_delays(vec![Duration::from_millis(1); 2])
            .link_delay(Some(Duration::from_micros(5)))
            .threads(3)
            .build();
        assert_eq!(cfg.seed, 99);
        assert!(!cfg.verify);
        assert_eq!(cfg.worker_delays.len(), 2);
        assert_eq!(cfg.link_delay, Some(Duration::from_micros(5)));
        assert_eq!(cfg.threads, 3);
    }
}
