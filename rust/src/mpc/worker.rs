//! Phase 2 — worker computation and inter-worker exchange (eq. 17–20),
//! served by **persistent** worker threads.
//!
//! A worker thread lives as long as its deployment and serves any number of
//! jobs multiplexed over the shared fabric. For each job `n`:
//! 1. receives a [`ControlMsg::JobStart`] (per-job seed + overhead counters)
//!    and its shares `(F_A(αₙ), F_B(αₙ))` — in either order, interleaved
//!    with other jobs' traffic,
//! 2. computes `H(αₙ) = F_A(αₙ)·F_B(αₙ)` on the configured backend,
//! 3. forms `Gₙ(x) = Σ_{i,l} rₙ^{(i,l)} H(αₙ) x^{i+t·l} + Σ_w R_w x^{t²+w}`
//!    with `z` fresh uniform mask matrices `R_w` drawn from a per-job rng
//!    derived from `seed` (byte-identical to the legacy spawn-per-job path),
//! 4. sends `Gₙ(αₙ')` to every peer — payload buffers loaned from the
//!    fabric [`BufferPool`] — and accumulates received shares into
//!    `I(αₙ) = Σₙ' Gₙ'(αₙ)`,
//! 5. sends `I(αₙ)` then [`ControlMsg::JobDone`] to the master and forgets
//!    the job.
//!
//! Scaled-`H` copies and mask matrices live in per-thread buffers reused
//! across jobs, so a warm worker performs no fabric-payload allocations.
//! G-shares from faster peers arriving before this worker's own compute are
//! buffered per job; a receive timeout (a peer thread died mid-job) fails
//! the pending jobs with a typed [`ControlMsg::JobError`] instead of
//! deadlocking, and the thread keeps serving.
//!
//! Overhead counters are incremented exactly where the proofs of
//! Corollaries 10–11 place them, so integration tests can assert
//! `measured == ξ, σ` per worker and per job.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::ff;
use crate::matrix::FpMat;
use crate::metrics::WorkerCounters;
use crate::mpc::network::{BufferPool, ControlMsg, Endpoint, Fabric, JobId, Payload, PooledMat};
use crate::runtime::MatmulBackend;
use crate::util::rng::ChaChaRng;

/// Everything worker `n` needs before its serve loop starts (job-independent
/// deployment state; per-job seed and counters arrive via
/// [`ControlMsg::JobStart`]).
pub struct WorkerCtx {
    pub id: usize,
    pub n_workers: usize,
    pub t: usize,
    pub z: usize,
    /// Public evaluation points α₁..α_N (index = worker id).
    pub alphas: Arc<Vec<u64>>,
    /// This worker's reconstruction coefficients `rₙ^{(i,l)}`, indexed
    /// `i + t·l` (distributed by the coordinator; eq. 18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    /// Injected compute delay per job (straggler model).
    pub delay: Duration,
    /// How long to wait mid-job before declaring peers dead.
    pub recv_timeout: Duration,
}

/// In-flight state of one job at one worker.
#[derive(Default)]
struct JobState {
    /// Per-job seed + overhead counters from [`ControlMsg::JobStart`].
    start: Option<(u64, Arc<WorkerCounters>)>,
    /// Phase-1 shares, held until the compute phase consumes them.
    shares: Option<(PooledMat, PooledMat)>,
    /// G-shares from peers that computed before us.
    early_g: Vec<PooledMat>,
    /// Own `I(αₙ)` accumulator; present once the compute phase ran.
    i_share: Option<PooledMat>,
    /// Peer G-shares folded into `i_share` so far.
    received: usize,
}

/// Per-thread compute buffers reused across every job the worker serves.
#[derive(Default)]
struct ComputeScratch {
    /// `rₙ^{(i,l)}·H` — the t² scaled copies.
    scaled: Vec<FpMat>,
    /// The z uniform masks `R_w`.
    masks: Vec<FpMat>,
    /// Unreduced accumulator for the delayed-reduction G evaluation.
    acc: Vec<u64>,
}

/// Serve jobs until [`ControlMsg::Shutdown`] arrives (or the fabric closes).
///
/// The loop is a per-job state machine keyed by the envelopes' [`JobId`]:
/// messages from concurrent jobs interleave arbitrarily and are buffered
/// per job until that job can advance. A job-level failure (backend error,
/// unreachable peer, receive timeout) is reported to the master as a
/// [`ControlMsg::JobError`] and only kills that job — the thread keeps
/// serving.
pub fn serve_worker(
    ctx: WorkerCtx,
    endpoint: Endpoint,
    fabric: Arc<Fabric>,
    mut backend: Box<dyn MatmulBackend>,
    bufs: Arc<BufferPool>,
) -> Result<()> {
    let mut jobs: HashMap<JobId, JobState> = HashMap::new();
    let mut scratch = ComputeScratch::default();
    // Ring of recently failed jobs: late envelopes from their slower peers
    // must be dropped, not resurrected into phantom `JobState`s that would
    // pin pooled buffers forever and re-fail on the next timeout. Job ids
    // are never reused, so a tombstone can only ever suppress stale
    // traffic; the ring is bounded because failures are rare and a
    // straggling peer delivers within one receive window.
    let mut failed: VecDeque<JobId> = VecDeque::with_capacity(FAILED_RING);
    loop {
        let env = if jobs.is_empty() {
            // Idle: block until the next job (or shutdown). A closed fabric
            // means the runtime is gone — exit cleanly.
            match endpoint.recv() {
                Ok(env) => env,
                Err(_) => return Ok(()),
            }
        } else {
            match endpoint.recv_timeout_raw(ctx.recv_timeout) {
                Ok(env) => env,
                Err(RecvTimeoutError::Timeout) => {
                    // A peer thread died mid-job: fail every pending job
                    // with a typed error instead of deadlocking, then keep
                    // serving new jobs. (Per-job deadlines that spare
                    // healthy concurrent jobs are a ROADMAP follow-up.)
                    for (job, _state) in jobs.drain() {
                        remember_failed(&mut failed, job);
                        let _ = fabric.send(
                            job,
                            ctx.id,
                            fabric.master_id(),
                            Payload::Control(ControlMsg::JobError(format!(
                                "worker {}: no job traffic within {:?} (dead peer?)",
                                ctx.id, ctx.recv_timeout
                            ))),
                        );
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
        };
        let job = env.job;
        if matches!(env.payload, Payload::Control(ControlMsg::Shutdown)) {
            return Ok(());
        }
        if failed.contains(&job) {
            continue; // stale traffic for a job this worker already failed
        }
        match env.payload {
            Payload::Control(ControlMsg::JobAbort) => {
                // The driver gave up on this job (a peer failed or its
                // receive timed out): drop whatever state we hold and
                // tombstone the id so a slow peer's G-share cannot
                // resurrect it.
                jobs.remove(&job);
                remember_failed(&mut failed, job);
            }
            Payload::Control(ControlMsg::JobStart { seed, counters }) => {
                jobs.entry(job).or_default().start = Some((seed, counters));
            }
            Payload::Shares { fa, fb } => {
                jobs.entry(job).or_default().shares = Some((fa, fb));
            }
            Payload::GShare(g) => {
                let st = jobs.entry(job).or_default();
                if let Some(i_share) = st.i_share.as_mut() {
                    let (_, counters) = st.start.as_ref().expect("computed implies started");
                    counters.add_stored(g.len() as u64);
                    i_share.add_assign(&g);
                    st.received += 1;
                } else {
                    st.early_g.push(g);
                }
            }
            // IShare / JobDone / JobError never legally target a worker;
            // report the routing bug for that job and drop its state.
            other => {
                jobs.remove(&job);
                remember_failed(&mut failed, job);
                let _ = fabric.send(
                    job,
                    ctx.id,
                    fabric.master_id(),
                    Payload::Control(ControlMsg::JobError(format!(
                        "worker {}: unexpected {other:?}",
                        ctx.id
                    ))),
                );
                continue;
            }
        }
        if let Some(st) = jobs.get_mut(&job) {
            match advance_job(&ctx, job, st, &fabric, &bufs, backend.as_mut(), &mut scratch) {
                Ok(true) => {
                    jobs.remove(&job);
                }
                Ok(false) => {}
                Err(e) => {
                    jobs.remove(&job);
                    remember_failed(&mut failed, job);
                    let _ = fabric.send(
                        job,
                        ctx.id,
                        fabric.master_id(),
                        Payload::Control(ControlMsg::JobError(format!(
                            "worker {}: {e}",
                            ctx.id
                        ))),
                    );
                }
            }
        }
    }
}

/// Tombstone capacity for the recently-failed ring (see `serve_worker`).
const FAILED_RING: usize = 64;

fn remember_failed(failed: &mut VecDeque<JobId>, job: JobId) {
    if failed.len() == FAILED_RING {
        failed.pop_front();
    }
    failed.push_back(job);
}

/// Push one job as far as its buffered state allows. Returns `Ok(true)`
/// when the job is complete (I-share and JobDone sent).
fn advance_job(
    ctx: &WorkerCtx,
    job: JobId,
    st: &mut JobState,
    fabric: &Arc<Fabric>,
    bufs: &Arc<BufferPool>,
    backend: &mut dyn MatmulBackend,
    scratch: &mut ComputeScratch,
) -> Result<bool> {
    if st.i_share.is_none() {
        if st.start.is_none() || st.shares.is_none() {
            return Ok(false); // still waiting for JobStart or shares
        }
        compute_phase(ctx, job, st, fabric, bufs, backend, scratch)?;
    }
    if st.received == ctx.n_workers - 1 {
        let (_, counters) = st.start.as_ref().expect("computed implies started");
        let i_share = st.i_share.take().expect("i_share present");
        counters.add_stored(i_share.len() as u64);
        fabric.send(job, ctx.id, fabric.master_id(), Payload::IShare(i_share))?;
        fabric.send(
            job,
            ctx.id,
            fabric.master_id(),
            Payload::Control(ControlMsg::JobDone),
        )?;
        return Ok(true);
    }
    Ok(false)
}

/// The Phase-2 compute: `H = F_A·F_B`, the t² scaled copies, the z masks,
/// and the `N` G-share evaluations (sent to peers / kept as the I-share
/// seed). Buffered early G-shares are folded in at the end.
fn compute_phase(
    ctx: &WorkerCtx,
    job: JobId,
    st: &mut JobState,
    fabric: &Arc<Fabric>,
    bufs: &Arc<BufferPool>,
    backend: &mut dyn MatmulBackend,
    s: &mut ComputeScratch,
) -> Result<()> {
    let t2 = ctx.t * ctx.t;
    let (seed, counters) = {
        let (seed, c) = st.start.as_ref().expect("started");
        (*seed, c.clone())
    };
    let (fa, fb) = st.shares.take().expect("shares present");
    counters.add_stored((fa.len() + fb.len()) as u64);

    if !ctx.delay.is_zero() {
        std::thread::sleep(ctx.delay);
    }

    // --- H(αₙ) = F_A(αₙ)·F_B(αₙ) ---
    let h = backend.matmul_mod(&fa, &fb)?;
    // m³/(st²) scalar multiplications (Corollary 10, term 1).
    counters.add_mults((fa.rows * fa.cols * fb.cols) as u64);
    counters.add_stored(h.len() as u64);
    // Return the share buffers to the pool before loaning G buffers, so a
    // steady-state job cycles a fixed working set.
    drop(fa);
    drop(fb);

    // --- rₙ^{(i,l)}·H — t² scaled copies (m² multiplications, term 2) ---
    let my_r = &ctx.r_coeffs[ctx.id];
    debug_assert_eq!(my_r.len(), t2);
    while s.scaled.len() < t2 {
        s.scaled.push(FpMat::zeros(0, 0));
    }
    for (sc, &r) in s.scaled.iter_mut().zip(my_r.iter()) {
        h.scale_into(r, sc);
    }
    counters.add_mults((t2 * h.len()) as u64);
    // the t² Lagrange coefficients are worker-resident state (σ term).
    counters.add_stored(t2 as u64);

    // --- z uniform masks R_w, from the per-job secret stream ---
    // The stream must match the legacy spawn-per-job path byte for byte:
    // that path forked the job rng for source A, source B, then workers
    // 0..N in order, so worker `id` discards 2 + id forks and takes the
    // next one.
    let mut job_rng = ChaChaRng::seed_from_u64(seed);
    for _ in 0..2 + ctx.id {
        let _ = job_rng.fork();
    }
    let mut rng = job_rng.fork();
    while s.masks.len() < ctx.z {
        s.masks.push(FpMat::zeros(0, 0));
    }
    for mask in s.masks.iter_mut().take(ctx.z) {
        mask.reshape(h.rows, h.cols);
        mask.fill_random(&mut rng);
    }
    counters.add_stored((ctx.z * h.len()) as u64);

    // --- evaluate Gₙ at every peer point and send ---
    // G = scaled[0]·α⁰ + Σ_{il>0} scaled[il]·α^{il} + Σ_w R_w·α^{t²+w},
    // combined in one delayed-reduction pass per peer; the coefficient list
    // and the unreduced accumulator persist across jobs, and the G payload
    // buffers are loaned from the fabric pool.
    let mut own_g: Option<PooledMat> = None;
    let mut terms: Vec<(u64, &[u32])> = Vec::with_capacity(t2 + ctx.z);
    for peer in 0..ctx.n_workers {
        let alpha = ctx.alphas[peer];
        let mut g = BufferPool::loan(bufs, h.rows, h.cols);
        terms.clear();
        let mut ap = 1u64; // α^il incrementally
        for sc in s.scaled.iter().take(t2) {
            terms.push((ap, &sc.data));
            ap = ff::mul(ap, alpha);
        }
        for mask in s.masks.iter().take(ctx.z) {
            terms.push((ap, &mask.data));
            ap = ff::mul(ap, alpha);
        }
        ff::weighted_sum_with_scratch(&mut g.data, &terms, &mut s.acc);
        // (t²−1+z)·m²/t² multiplications per peer (Corollary 10, term 3).
        counters.add_mults(((t2 - 1 + ctx.z) * h.len()) as u64);
        // each computed evaluation is worker state before transmission (σ).
        counters.add_stored(h.len() as u64);
        if peer == ctx.id {
            own_g = Some(g);
        } else {
            fabric.send(job, ctx.id, peer, Payload::GShare(g))?;
        }
    }

    // --- start accumulating I(αₙ) = Σ Gₙ'(αₙ) from buffered arrivals ---
    let mut i_share = own_g.expect("own G computed");
    for g in st.early_g.drain(..) {
        counters.add_stored(g.len() as u64);
        i_share.add_assign(&g);
        st.received += 1;
    }
    st.i_share = Some(i_share);
    Ok(())
}
