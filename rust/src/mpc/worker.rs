//! Phase 2 — worker computation and inter-worker exchange (eq. 17–20),
//! served by **persistent** worker threads.
//!
//! A worker thread lives as long as its deployment and serves any number of
//! jobs multiplexed over the shared fabric. For each job `n`:
//! 1. receives a [`ControlMsg::JobStart`] (per-job seed + overhead counters)
//!    and its shares `(F_A(αₙ), F_B(αₙ))` — in either order, interleaved
//!    with other jobs' traffic; the shares arrive combined (one in-process
//!    driver playing both sources) or split across [`Payload::ShareA`] /
//!    [`Payload::ShareB`] envelopes from two separate source processes,
//! 2. computes `H(αₙ) = F_A(αₙ)·F_B(αₙ)` on the configured backend,
//! 3. forms `Gₙ(x) = Σ_{i,l} rₙ^{(i,l)} H(αₙ) x^{i+t·l} + Σ_w R_w x^{t²+w}`
//!    with `z` fresh uniform mask matrices `R_w` drawn from a per-job rng
//!    derived from `seed` (byte-identical to the legacy spawn-per-job path),
//! 4. sends `Gₙ(αₙ')` to every peer — payload buffers loaned from the
//!    fabric [`BufferPool`] — and accumulates received shares into
//!    `I(αₙ) = Σₙ' Gₙ'(αₙ)`,
//! 5. sends `I(αₙ)` then [`ControlMsg::JobDone`] to the master and forgets
//!    the job.
//!
//! **Pipeline stages.** A [`ControlMsg::StageStart`] runs the same state
//! machine with two extensions: the job carries a stage tag, and — when the
//! stage's output feeds another stage — a *masked-open* flag. A masked
//! stage withholds its plain I-share; it waits for source B's blinding-mask
//! share ([`Payload::StageMask`]), adds it, and sends the blinded sum as
//! [`Payload::StageMasked`], so the master only ever interpolates the
//! uniformly masked `Z = Y + R`. The next stage's A-side share may arrive
//! either as an ordinary combined share or split across
//! [`ControlMsg::StageShareZ`] (from the master) and
//! [`ControlMsg::StageShareR`] (from source A), which the worker subtracts
//! into `F_A(αₙ)` of `X = Z' − R'` before computing as usual.
//!
//! Scaled-`H` copies and mask matrices live in per-thread buffers reused
//! across jobs, so a warm worker performs no fabric-payload allocations.
//! G-shares from faster peers arriving before this worker's own compute are
//! buffered per job.
//!
//! **Per-job deadlines.** Every in-flight job tracks the instant of its
//! last envelope; a job that makes no progress for `recv_timeout` is failed
//! with a typed [`ControlMsg::JobError`] — *only that job*. A healthy
//! concurrent job keeps flowing while a sibling starves on a dead peer (the
//! straggler-isolation contract pinned by `tests/error_paths.rs`). A worker
//! that hits `max_deadline_misses` deadline-miss rounds *with no envelope
//! received in between* (any traffic proves the link alive and resets the
//! count) self-evicts — failing its remaining jobs loudly and exiting its
//! loop — so the runtime's reaper can replace it; a worker killed by the
//! chaos plan exits the same way a crashed thread would, without reporting
//! anything.
//!
//! Overhead counters are incremented exactly where the proofs of
//! Corollaries 10–11 place them, so integration tests can assert
//! `measured == ξ, σ` per worker and per job.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::ff;
use crate::matrix::FpMat;
use crate::metrics::{RuntimeCounters, WorkerCounters};
use crate::mpc::network::{BufferPool, ControlMsg, Endpoint, Fabric, JobId, Payload, PooledMat};
use crate::runtime::MatmulBackend;
use crate::util::rng::ChaChaRng;

/// Everything worker `n` needs before its serve loop starts (job-independent
/// deployment state; per-job seed and counters arrive via
/// [`ControlMsg::JobStart`]).
pub struct WorkerCtx {
    /// This worker's index `n` (also its fabric node id).
    pub id: usize,
    /// Fleet size `N`.
    pub n_workers: usize,
    /// Column partition factor of the scheme.
    pub t: usize,
    /// Collusion tolerance of the scheme.
    pub z: usize,
    /// Public evaluation points α₁..α_N (index = worker id).
    pub alphas: Arc<Vec<u64>>,
    /// This worker's reconstruction coefficients `rₙ^{(i,l)}`, indexed
    /// `i + t·l` (distributed by the coordinator; eq. 18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    /// Injected compute delay per job (straggler model).
    pub delay: Duration,
    /// Per-job deadline: a job with no traffic for this long is failed
    /// (that job only — concurrent jobs keep their own deadlines).
    pub recv_timeout: Duration,
    /// Consecutive deadline-miss rounds after which the worker self-evicts
    /// for the runtime's reaper to replace.
    pub max_deadline_misses: usize,
    /// How long the serve loop may sit **idle** (no jobs in flight, no
    /// envelope at all) before exiting cleanly. `None` — the in-process
    /// runtime default — blocks forever (the runtime owns the thread's
    /// lifecycle via `Shutdown`). Multi-process node workers set a bound
    /// so a worker orphaned by a killed master process terminates instead
    /// of leaking.
    pub idle_timeout: Option<Duration>,
    /// Runtime-level health counters (deadline misses are recorded here).
    pub health: Arc<RuntimeCounters>,
}

/// In-flight state of one job at one worker.
struct JobState {
    /// Per-job seed + overhead counters from [`ControlMsg::JobStart`].
    start: Option<(u64, Arc<WorkerCounters>)>,
    /// Phase-1 `F_A(αₙ)` share — from the combined in-process envelope or
    /// a separate source-A process's [`Payload::ShareA`].
    share_a: Option<PooledMat>,
    /// Phase-1 `F_B(αₙ)` share (combined envelope or [`Payload::ShareB`]).
    share_b: Option<PooledMat>,
    /// Pipeline stage index ([`ControlMsg::StageStart`]); 0 for ordinary
    /// single-matmul jobs, echoed back in [`Payload::StageMasked`].
    stage: u32,
    /// Whether this stage ends with a masked open: the finished I-share is
    /// withheld, blinded with source B's mask share, and sent as
    /// [`Payload::StageMasked`] instead of a plain [`Payload::IShare`].
    masked: bool,
    /// Source B's blinding-mask share `D(αₙ)` (masked stages only).
    mask: Option<PooledMat>,
    /// The master's half of a split pipeline re-share: its evaluation of
    /// the coded polynomial of the blinded opening `Z' = Y' + R'`
    /// ([`ControlMsg::StageShareZ`]).
    stage_z: Option<FpMat>,
    /// Source A's half of the split re-share: its evaluation of the coded
    /// polynomial of the transformed mask `R'`
    /// ([`ControlMsg::StageShareR`]).
    stage_r: Option<FpMat>,
    /// G-shares from peers that computed before us.
    early_g: Vec<PooledMat>,
    /// Own `I(αₙ)` accumulator; present once the compute phase ran.
    i_share: Option<PooledMat>,
    /// Peer G-shares folded into `i_share` so far.
    received: usize,
    /// Deadline basis: refreshed on every envelope of this job. The job
    /// expires `recv_timeout` after this instant.
    last_progress: Instant,
}

impl JobState {
    fn new() -> JobState {
        JobState {
            start: None,
            share_a: None,
            share_b: None,
            stage: 0,
            masked: false,
            mask: None,
            stage_z: None,
            stage_r: None,
            early_g: Vec::new(),
            i_share: None,
            received: 0,
            last_progress: Instant::now(),
        }
    }

    /// Current overhead totals (zeros before the job started counting) —
    /// what `JobDone`/`AbortAck` report back to the driver.
    fn counter_totals(&self) -> (u64, u64) {
        match &self.start {
            Some((_, c)) => (c.mults(), c.stored()),
            None => (0, 0),
        }
    }
}

/// Per-thread compute buffers reused across every job the worker serves.
#[derive(Default)]
struct ComputeScratch {
    /// `rₙ^{(i,l)}·H` — the t² scaled copies.
    scaled: Vec<FpMat>,
    /// The z uniform masks `R_w`.
    masks: Vec<FpMat>,
    /// Unreduced accumulator for the delayed-reduction G evaluation.
    acc: Vec<u64>,
}

/// Serve jobs until [`ControlMsg::Shutdown`] arrives (or the fabric closes).
///
/// The loop is a per-job state machine keyed by the envelopes' [`JobId`]:
/// messages from concurrent jobs interleave arbitrarily and are buffered
/// per job until that job can advance. A job-level failure (backend error,
/// unreachable peer, an expired per-job deadline) is reported to the master
/// as a [`ControlMsg::JobError`] and only kills that job — the thread keeps
/// serving. The loop itself exits three ways: a `Shutdown` (clean runtime
/// teardown), a chaos kill (simulated crash — no report, state dropped),
/// or self-eviction after `max_deadline_misses` consecutive deadline-miss
/// rounds (returned as a typed error for the reaper's eviction record).
pub fn serve_worker(
    ctx: WorkerCtx,
    endpoint: Endpoint,
    fabric: Arc<Fabric>,
    mut backend: Box<dyn MatmulBackend>,
    bufs: Arc<BufferPool>,
) -> Result<()> {
    let mut jobs: HashMap<JobId, JobState> = HashMap::new();
    let mut scratch = ComputeScratch::default();
    // Tombstones of recently failed/aborted jobs: late envelopes from
    // their slower peers must be dropped, not resurrected into phantom
    // `JobState`s that would pin pooled buffers and re-fail on the next
    // timeout. Job ids are never reused, so a tombstone can only ever
    // suppress stale traffic. Since early decode made JobAbort a routine
    // per-job event (not just a failure path), the set is sized so a peer
    // would have to straggle *hundreds of jobs* behind before its
    // tombstone rotates out — and membership stays O(1) per envelope.
    let mut failed = Tombstones::new();
    // Deadline-miss rounds since the last received envelope (self-eviction
    // trigger): a worker that starves repeatedly with no traffic at all in
    // between is likely wedged behind a partitioned link and is cheaper to
    // replace than to trust.
    let mut consecutive_misses = 0usize;
    loop {
        let env = if jobs.is_empty() {
            // Idle: block until the next job (or shutdown). A closed fabric
            // means the runtime is gone — exit cleanly. With an idle bound
            // (multi-process node workers), a silent fabric eventually
            // means an orphaned process: exit cleanly too.
            match ctx.idle_timeout {
                None => match endpoint.recv() {
                    Ok(env) => env,
                    Err(_) => return Ok(()),
                },
                Some(limit) => match endpoint.recv_timeout_raw(limit) {
                    Ok(env) => env,
                    Err(_) => return Ok(()),
                },
            }
        } else {
            // Wait no longer than the earliest per-job deadline.
            let next_expiry = jobs
                .values()
                .map(|st| st.last_progress + ctx.recv_timeout)
                .min()
                .expect("jobs nonempty");
            let wait = next_expiry.saturating_duration_since(Instant::now());
            match endpoint.recv_timeout_raw(wait) {
                Ok(env) => env,
                Err(RecvTimeoutError::Timeout) => {
                    // Fail ONLY the expired jobs — a healthy concurrent job
                    // survives its sibling's dead peer.
                    let now = Instant::now();
                    let expired: Vec<JobId> = jobs
                        .iter()
                        .filter(|(_, st)| {
                            now.saturating_duration_since(st.last_progress)
                                >= ctx.recv_timeout
                        })
                        .map(|(&job, _)| job)
                        .collect();
                    if expired.is_empty() {
                        continue; // raced a refresh; recompute the wait
                    }
                    for job in expired {
                        jobs.remove(&job);
                        failed.insert(job);
                        ctx.health.deadline_misses.fetch_add(1, Ordering::Relaxed);
                        let _ = fabric.send(
                            job,
                            ctx.id,
                            fabric.master_id(),
                            Payload::Control(ControlMsg::JobError(format!(
                                "worker {}: job deadline expired — no job-{job} \
                                 traffic within {:?} (dead peer?)",
                                ctx.id, ctx.recv_timeout
                            ))),
                        );
                    }
                    consecutive_misses += 1;
                    if consecutive_misses >= ctx.max_deadline_misses {
                        // Fail the still-healthy in-flight jobs loudly
                        // before leaving: their masters should fail fast on
                        // a JobError, not sit out their own full deadline
                        // wondering where this worker went.
                        for (job, _state) in jobs.drain() {
                            let _ = fabric.send(
                                job,
                                ctx.id,
                                fabric.master_id(),
                                Payload::Control(ControlMsg::JobError(format!(
                                    "worker {}: self-evicting (consecutive \
                                     deadline misses)",
                                    ctx.id
                                ))),
                            );
                        }
                        return Err(CmpcError::Fabric(format!(
                            "worker {}: self-evicted after {consecutive_misses} \
                             consecutive deadline-miss rounds",
                            ctx.id
                        )));
                    }
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => return Ok(()),
            }
        };
        // Any received envelope proves the link is alive, so deadline-miss
        // rounds are only "consecutive" when nothing at all arrives between
        // them — isolated dead-peer incidents spread over a long serving
        // life must not accumulate into a spurious self-eviction.
        consecutive_misses = 0;
        let job = env.job;
        if matches!(env.payload, Payload::Control(ControlMsg::Shutdown)) {
            return Ok(());
        }
        if failed.contains(job) {
            continue; // stale traffic for a job this worker already failed
        }
        match env.payload {
            Payload::Control(ControlMsg::JobAbort) => {
                // The driver gave up on this job (a peer failed or its
                // receive timed out) or the master early-decoded and no
                // longer needs the tail: drop whatever state we hold,
                // tombstone the id so a slow peer's G-share cannot
                // resurrect it, and acknowledge with our final counter
                // totals — after the tombstone, nothing can tick them, so
                // the driver's ξ/σ report is exact, not a lower bound.
                let totals = jobs.remove(&job).map(|st| st.counter_totals());
                failed.insert(job);
                let (mults, stored) = totals.unwrap_or((0, 0));
                let _ = fabric.send(
                    job,
                    ctx.id,
                    fabric.master_id(),
                    Payload::Control(ControlMsg::AbortAck { mults, stored }),
                );
            }
            Payload::Control(ControlMsg::JobStart { seed, counters }) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.start = Some((seed, counters));
                st.last_progress = Instant::now();
            }
            Payload::Shares { fa, fb } => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.share_a = Some(fa);
                st.share_b = Some(fb);
                st.last_progress = Instant::now();
            }
            Payload::ShareA(fa) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.share_a = Some(fa);
                st.last_progress = Instant::now();
            }
            Payload::ShareB(fb) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.share_b = Some(fb);
                st.last_progress = Instant::now();
            }
            Payload::GShare(g) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.last_progress = Instant::now();
                if let Some(i_share) = st.i_share.as_mut() {
                    let (_, counters) = st.start.as_ref().expect("computed implies started");
                    counters.add_stored(g.len() as u64);
                    i_share.add_assign(&g);
                    st.received += 1;
                } else {
                    st.early_g.push(g);
                }
            }
            Payload::Control(ControlMsg::StageStart { stage, seed, masked, counters }) => {
                // A pipeline stage begins exactly like a JobStart, plus the
                // stage tag and the masked-open flag. The flag arrives
                // *before* any share can complete the job, so a masked
                // stage can never leak a plain I-share by racing its mask.
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.start = Some((seed, counters));
                st.stage = stage;
                st.masked = masked;
                st.last_progress = Instant::now();
            }
            Payload::Control(ControlMsg::StageShareZ { mat, .. }) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.stage_z = Some(mat);
                st.last_progress = Instant::now();
            }
            Payload::Control(ControlMsg::StageShareR { mat, .. }) => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.stage_r = Some(mat);
                st.last_progress = Instant::now();
            }
            Payload::StageMask { mat, .. } => {
                let st = jobs.entry(job).or_insert_with(JobState::new);
                st.mask = Some(mat);
                st.last_progress = Instant::now();
            }
            // IShare / JobDone / JobError / AbortAck never legally target
            // a worker; report the routing bug for that job and drop its
            // state.
            other => {
                jobs.remove(&job);
                failed.insert(job);
                let _ = fabric.send(
                    job,
                    ctx.id,
                    fabric.master_id(),
                    Payload::Control(ControlMsg::JobError(format!(
                        "worker {}: unexpected {other:?}",
                        ctx.id
                    ))),
                );
                continue;
            }
        }
        if let Some(st) = jobs.get_mut(&job) {
            match advance_job(&ctx, job, st, &fabric, &bufs, backend.as_mut(), &mut scratch) {
                Ok(true) => {
                    jobs.remove(&job);
                }
                Ok(false) => {}
                Err(e) => {
                    jobs.remove(&job);
                    failed.insert(job);
                    if fabric.chaos_killed(ctx.id) {
                        // The chaos plan killed this worker mid-send: die
                        // like a crashed thread — no JobError (a crashed
                        // node cannot report), state dropped (its pooled
                        // buffers return to the pool as the maps unwind).
                        return Ok(());
                    }
                    let _ = fabric.send(
                        job,
                        ctx.id,
                        fabric.master_id(),
                        Payload::Control(ControlMsg::JobError(format!(
                            "worker {}: {e}",
                            ctx.id
                        ))),
                    );
                }
            }
        }
    }
}

/// Tombstone capacity for the recently-failed/aborted set (see
/// `serve_worker`). Early decode aborts every job at its stragglers, so
/// this is sized for routine use: a stale envelope only slips through if
/// its sender is more than `FAILED_RING` jobs behind the present.
const FAILED_RING: usize = 1024;

/// Bounded tombstone set: O(1) membership (the per-envelope hot path) with
/// FIFO eviction once `FAILED_RING` ids are retained.
struct Tombstones {
    order: VecDeque<JobId>,
    set: HashSet<JobId>,
}

impl Tombstones {
    fn new() -> Tombstones {
        Tombstones {
            order: VecDeque::with_capacity(FAILED_RING),
            set: HashSet::with_capacity(FAILED_RING),
        }
    }

    fn contains(&self, job: JobId) -> bool {
        self.set.contains(&job)
    }

    fn insert(&mut self, job: JobId) {
        if !self.set.insert(job) {
            return; // already tombstoned; keep its original eviction slot
        }
        if self.order.len() == FAILED_RING {
            if let Some(evicted) = self.order.pop_front() {
                self.set.remove(&evicted);
            }
        }
        self.order.push_back(job);
    }
}

/// Push one job as far as its buffered state allows. Returns `Ok(true)`
/// when the job is complete (I-share and JobDone sent).
fn advance_job(
    ctx: &WorkerCtx,
    job: JobId,
    st: &mut JobState,
    fabric: &Arc<Fabric>,
    bufs: &Arc<BufferPool>,
    backend: &mut dyn MatmulBackend,
    scratch: &mut ComputeScratch,
) -> Result<bool> {
    if st.share_a.is_none() && st.stage_z.is_some() && st.stage_r.is_some() {
        // Split pipeline re-share: the next stage's input is X = Z' − R',
        // so F_A(αₙ) of X is the difference of the two halves' coded
        // evaluations — GF(p) linearity makes this byte-identical to a
        // single source encoding X directly with the same secret draws.
        let mut z = st.stage_z.take().expect("checked above");
        let r = st.stage_r.take().expect("checked above");
        z.axpy_inplace(ff::P - 1, &r);
        st.share_a = Some(PooledMat::detached(z));
    }
    if st.i_share.is_none() {
        if st.start.is_none() || st.share_a.is_none() || st.share_b.is_none() {
            return Ok(false); // still waiting for JobStart or either share
        }
        compute_phase(ctx, job, st, fabric, bufs, backend, scratch)?;
    }
    if st.received == ctx.n_workers - 1 {
        if st.masked && st.mask.is_none() {
            return Ok(false); // I-share finished; blinding mask still in flight
        }
        let (_, counters) = st.start.as_ref().expect("computed implies started");
        let counters = counters.clone();
        let mut i_share = st.i_share.take().expect("i_share present");
        if st.masked {
            // Masked open: blind the I-share with source B's mask share so
            // the master's per-stage interpolation recovers Z = Y + R, a
            // uniformly masked image of the true intermediate Y.
            let mask = st.mask.take().expect("checked above");
            counters.add_stored(mask.len() as u64);
            i_share.add_assign(&mask);
        }
        counters.add_stored(i_share.len() as u64);
        // Totals are final here — the worker never touches this job's
        // counters again — so JobDone can carry them (the driver-side
        // counters of a *remote* worker are set from exactly this). The
        // I-share and JobDone travel as one batch: over TCP that is a
        // single coalesced write, while metering and receive order stay
        // identical to two sequential sends.
        let (mults, stored) = (counters.mults(), counters.stored());
        let final_share = if st.masked {
            Payload::StageMasked { stage: st.stage, mat: i_share }
        } else {
            Payload::IShare(i_share)
        };
        fabric.send_batch(
            job,
            ctx.id,
            fabric.master_id(),
            vec![
                final_share,
                Payload::Control(ControlMsg::JobDone { mults, stored }),
            ],
        )?;
        return Ok(true);
    }
    Ok(false)
}

/// The Phase-2 compute: `H = F_A·F_B`, the t² scaled copies, the z masks,
/// and the `N` G-share evaluations (sent to peers / kept as the I-share
/// seed). Buffered early G-shares are folded in at the end.
fn compute_phase(
    ctx: &WorkerCtx,
    job: JobId,
    st: &mut JobState,
    fabric: &Arc<Fabric>,
    bufs: &Arc<BufferPool>,
    backend: &mut dyn MatmulBackend,
    s: &mut ComputeScratch,
) -> Result<()> {
    let t2 = ctx.t * ctx.t;
    let (seed, counters) = {
        let (seed, c) = st.start.as_ref().expect("started");
        (*seed, c.clone())
    };
    let fa = st.share_a.take().expect("share A present");
    let fb = st.share_b.take().expect("share B present");
    counters.add_stored((fa.len() + fb.len()) as u64);

    if !ctx.delay.is_zero() {
        std::thread::sleep(ctx.delay);
    }

    // --- H(αₙ) = F_A(αₙ)·F_B(αₙ) ---
    let h = backend.matmul_mod(&fa, &fb)?;
    // m³/(st²) scalar multiplications (Corollary 10, term 1).
    counters.add_mults((fa.rows * fa.cols * fb.cols) as u64);
    counters.add_stored(h.len() as u64);
    // Return the share buffers to the pool before loaning G buffers, so a
    // steady-state job cycles a fixed working set.
    drop(fa);
    drop(fb);

    // --- rₙ^{(i,l)}·H — t² scaled copies (m² multiplications, term 2) ---
    let my_r = &ctx.r_coeffs[ctx.id];
    debug_assert_eq!(my_r.len(), t2);
    while s.scaled.len() < t2 {
        s.scaled.push(FpMat::zeros(0, 0));
    }
    for (sc, &r) in s.scaled.iter_mut().zip(my_r.iter()) {
        h.scale_into(r, sc);
    }
    counters.add_mults((t2 * h.len()) as u64);
    // the t² Lagrange coefficients are worker-resident state (σ term).
    counters.add_stored(t2 as u64);

    // --- z uniform masks R_w, from the per-job secret stream ---
    // The stream must match the legacy spawn-per-job path byte for byte:
    // that path forked the job rng for source A, source B, then workers
    // 0..N in order, so worker `id` discards 2 + id forks and takes the
    // next one.
    let mut job_rng = ChaChaRng::seed_from_u64(seed);
    for _ in 0..2 + ctx.id {
        let _ = job_rng.fork();
    }
    let mut rng = job_rng.fork();
    while s.masks.len() < ctx.z {
        s.masks.push(FpMat::zeros(0, 0));
    }
    for mask in s.masks.iter_mut().take(ctx.z) {
        mask.reshape(h.rows, h.cols);
        mask.fill_random(&mut rng);
    }
    counters.add_stored((ctx.z * h.len()) as u64);

    // --- evaluate Gₙ at every peer point and send ---
    // G = scaled[0]·α⁰ + Σ_{il>0} scaled[il]·α^{il} + Σ_w R_w·α^{t²+w},
    // combined in one delayed-reduction pass per peer; the coefficient list
    // and the unreduced accumulator persist across jobs, and the G payload
    // buffers are loaned from the fabric pool.
    let mut own_g: Option<PooledMat> = None;
    let mut terms: Vec<(u64, &[u32])> = Vec::with_capacity(t2 + ctx.z);
    for peer in 0..ctx.n_workers {
        let alpha = ctx.alphas[peer];
        let mut g = BufferPool::loan(bufs, h.rows, h.cols);
        terms.clear();
        let mut ap = 1u64; // α^il incrementally
        for sc in s.scaled.iter().take(t2) {
            terms.push((ap, &sc.data));
            ap = ff::mul(ap, alpha);
        }
        for mask in s.masks.iter().take(ctx.z) {
            terms.push((ap, &mask.data));
            ap = ff::mul(ap, alpha);
        }
        ff::weighted_sum_with_scratch(&mut g.data, &terms, &mut s.acc);
        // (t²−1+z)·m²/t² multiplications per peer (Corollary 10, term 3).
        counters.add_mults(((t2 - 1 + ctx.z) * h.len()) as u64);
        // each computed evaluation is worker state before transmission (σ).
        counters.add_stored(h.len() as u64);
        if peer == ctx.id {
            own_g = Some(g);
        } else {
            fabric.send(job, ctx.id, peer, Payload::GShare(g))?;
        }
    }

    // --- start accumulating I(αₙ) = Σ Gₙ'(αₙ) from buffered arrivals ---
    let mut i_share = own_g.expect("own G computed");
    for g in st.early_g.drain(..) {
        counters.add_stored(g.len() as u64);
        i_share.add_assign(&g);
        st.received += 1;
    }
    st.i_share = Some(i_share);
    Ok(())
}
