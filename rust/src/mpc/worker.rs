//! Phase 2 — worker computation and inter-worker exchange (eq. 17–20).
//!
//! Worker `n`:
//! 1. receives its shares `(F_A(αₙ), F_B(αₙ))`,
//! 2. computes `H(αₙ) = F_A(αₙ)·F_B(αₙ)` on the configured backend,
//! 3. forms `Gₙ(x) = Σ_{i,l} rₙ^{(i,l)} H(αₙ) x^{i+t·l} + Σ_w R_w x^{t²+w}`
//!    with `z` fresh uniform mask matrices `R_w`,
//! 4. sends `Gₙ(αₙ')` to every peer `n'` and accumulates received shares
//!    into `I(αₙ) = Σₙ' Gₙ'(αₙ)`,
//! 5. sends `I(αₙ)` to the master.
//!
//! Overhead counters are incremented exactly where the proofs of
//! Corollaries 10–11 place them, so integration tests can assert
//! `measured == ξ, σ` per worker.

use std::sync::Arc;
use std::time::Duration;

use crate::error::{CmpcError, Result};
use crate::ff;
use crate::matrix::FpMat;
use crate::metrics::WorkerCounters;
use crate::mpc::network::{Endpoint, Fabric, Payload};
use crate::runtime::MatmulBackend;
use crate::util::rng::ChaChaRng;

/// Everything worker `n` needs before its thread starts.
pub struct WorkerCtx {
    pub id: usize,
    pub n_workers: usize,
    pub t: usize,
    pub z: usize,
    /// Public evaluation points α₁..α_N (index = worker id).
    pub alphas: Arc<Vec<u64>>,
    /// This worker's reconstruction coefficients `rₙ^{(i,l)}`, indexed
    /// `i + t·l` (distributed by the coordinator; eq. 18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    /// Secret stream for the `R_w` masks.
    pub rng: ChaChaRng,
    pub counters: Arc<WorkerCounters>,
    /// Injected compute delay (straggler model).
    pub delay: Duration,
}

/// Run the Phase-2 worker loop to completion.
pub fn run_worker(
    mut ctx: WorkerCtx,
    endpoint: Endpoint,
    fabric: Arc<Fabric>,
    mut backend: Box<dyn MatmulBackend>,
) -> Result<()> {
    let n = ctx.n_workers;
    let t2 = ctx.t * ctx.t;
    // --- receive shares (Phase 1 tail) ---
    // Peers that got their shares earlier may already be pushing GShares at
    // us; buffer those until our own shares arrive.
    let mut early_g: Vec<FpMat> = Vec::new();
    let (fa, fb) = loop {
        let env = endpoint
            .recv()
            .map_err(|_| CmpcError::Fabric(format!("worker {} fabric closed", ctx.id)))?;
        match env.payload {
            Payload::Shares { fa, fb } => break (fa, fb),
            Payload::GShare(g) => early_g.push(g),
            other => {
                return Err(CmpcError::Fabric(format!(
                    "worker {}: unexpected {other:?}",
                    ctx.id
                )));
            }
        }
    };
    ctx.counters.add_stored((fa.len() + fb.len()) as u64);

    if !ctx.delay.is_zero() {
        std::thread::sleep(ctx.delay);
    }

    // --- H(αₙ) = F_A(αₙ)·F_B(αₙ) ---
    let h = backend.matmul_mod(&fa, &fb)?;
    // m³/(st²) scalar multiplications (Corollary 10, term 1).
    ctx.counters
        .add_mults((fa.rows * fa.cols * fb.cols) as u64);
    ctx.counters.add_stored(h.len() as u64);

    // --- rₙ^{(i,l)}·H — t² scaled copies (m² multiplications, term 2) ---
    let my_r = &ctx.r_coeffs[ctx.id];
    debug_assert_eq!(my_r.len(), t2);
    let scaled: Vec<FpMat> = my_r.iter().map(|&r| h.scale(r)).collect();
    ctx.counters.add_mults((t2 * h.len()) as u64);
    // the t² Lagrange coefficients are worker-resident state (σ term).
    ctx.counters.add_stored(t2 as u64);

    // --- z uniform masks R_w ---
    let masks: Vec<FpMat> = (0..ctx.z)
        .map(|_| FpMat::random(&mut ctx.rng, h.rows, h.cols))
        .collect();
    ctx.counters.add_stored((ctx.z * h.len()) as u64);

    // --- evaluate Gₙ at every peer point and send ---
    // The coefficient list and the unreduced accumulator are hoisted out of
    // the peer loop: one warmup growth, then N evaluations with zero
    // allocations beyond the G matrices themselves (which move into the
    // fabric envelopes).
    let mut own_g: Option<FpMat> = None;
    let mut terms: Vec<(u64, &[u32])> = Vec::with_capacity(t2 + ctx.z);
    let mut acc: Vec<u64> = Vec::new();
    for peer in 0..n {
        let alpha = ctx.alphas[peer];
        // G = scaled[0]·α⁰ + Σ_{il>0} scaled[il]·α^{il} + Σ_w R_w·α^{t²+w},
        // combined in one delayed-reduction pass (§Perf P4).
        let mut g = FpMat::zeros(h.rows, h.cols);
        terms.clear();
        let mut ap = 1u64; // α^il incrementally
        for sc in scaled.iter() {
            terms.push((ap, &sc.data));
            ap = ff::mul(ap, alpha);
        }
        for mask in masks.iter() {
            terms.push((ap, &mask.data));
            ap = ff::mul(ap, alpha);
        }
        ff::weighted_sum_with_scratch(&mut g.data, &terms, &mut acc);
        // (t²−1+z)·m²/t² multiplications per peer (Corollary 10, term 3).
        ctx.counters
            .add_mults(((t2 - 1 + ctx.z) * h.len()) as u64);
        // each computed evaluation is worker state before transmission (σ).
        ctx.counters.add_stored(h.len() as u64);
        if peer == ctx.id {
            own_g = Some(g);
        } else {
            // Peer may already be done only in failure teardown; surface it.
            fabric.send(ctx.id, peer, Payload::GShare(g)).map_err(|_| {
                CmpcError::Fabric(format!("worker {}: peer {peer} unreachable", ctx.id))
            })?;
        }
    }

    // --- accumulate I(αₙ) = Σ Gₙ'(αₙ) ---
    let mut i_share = own_g.expect("own G computed");
    let mut received = 0usize;
    for g in early_g {
        ctx.counters.add_stored(g.len() as u64);
        i_share.add_assign(&g);
        received += 1;
    }
    while received < n - 1 {
        let env = endpoint.recv().map_err(|_| {
            CmpcError::Fabric(format!("worker {}: fabric closed mid-exchange", ctx.id))
        })?;
        match env.payload {
            Payload::GShare(g) => {
                ctx.counters.add_stored(g.len() as u64);
                i_share.add_assign(&g);
                received += 1;
            }
            other => {
                return Err(CmpcError::Fabric(format!(
                    "worker {}: unexpected {other:?}",
                    ctx.id
                )));
            }
        }
    }
    ctx.counters.add_stored(i_share.len() as u64);

    // --- Phase 3 hand-off; the master may already have reconstructed from
    // t²+z faster peers and hung up, so a send error here is benign. ---
    let _ = fabric.send(ctx.id, fabric.master_id(), Payload::IShare(i_share));
    Ok(())
}
