//! Fused small-job batching — k same-shape jobs through one wide pass.
//!
//! The serving workloads this crate targets (gateway batches, coordinator
//! queues) are dominated by *small* jobs, where per-job fixed costs — power
//! tables, per-worker task dispatch, per-peer weighted-sum set-up — rival
//! the arithmetic itself. This module runs a batch of k same-shape jobs
//! through the protocol math as **one fused pass**: per worker, the k
//! per-job `H` products are stacked column-wise into a single wide buffer
//! (`k·len` scalars) and every subsequent kernel — the t² scaled copies,
//! the z masks, the N G-share evaluations, the I accumulation, and the
//! Phase-3 Vandermonde combination — operates on wide buffers, amortizing
//! its fixed cost across the whole batch. The wide fusion is legal because
//! the Lagrange coefficients `rₙ^{(i,l)}` and evaluation points `α` are
//! *per-worker*, not per-job: scaling a concatenation by `rₙ^{(i,l)}`
//! scales every job's segment correctly.
//!
//! Everything observable is **identical** to running the k jobs
//! sequentially through the fabric path:
//!
//! * every job keeps its own secret streams (the legacy fork order:
//!   source A, source B, then workers 0..N), so `Y`, the share
//!   polynomials, and the masks are byte-identical per job;
//! * per-worker ξ/σ counters tick the exact per-job amounts of the
//!   sequential worker (`mpc::worker::compute_phase`), bulk-applied;
//! * the per-job [`TrafficReport`] carries the scalars the fabric *would*
//!   have metered (N share pairs, N·(N−1) G-shares, N I-shares).
//!
//! What fusion deliberately skips: the fabric (no envelopes move, so
//! chaos plans, link shapers, and injected delays cannot be honored —
//! [`config_fusible`] gates on their absence), per-job `JobId` intake
//! (`Deployment::execute_fused` still counts each job for seed
//! derivation), and the early-decode/Byzantine machinery (the fused path
//! is in-process and trusted; shares cannot be garbled in transit).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::CmpcScheme;
use crate::error::{CmpcError, Result};
use crate::ff::{self, P};
use crate::matrix::FpMat;
use crate::metrics::{PhaseTimings, TrafficReport, WorkerCounters};
use crate::mpc::protocol::{
    validate_job_shapes, ExecEnv, ProtocolConfig, ProtocolOutput, Setup,
};
use crate::mpc::source;
use crate::poly::interp::try_vandermonde_inverse_rows;
use crate::util::rng::ChaChaRng;

/// Whether `config` permits the fused executor. Chaos plans, link shapers,
/// and injected delays are *fabric* behaviors; the fused path never touches
/// the fabric, so their presence forces the sequential path.
pub fn config_fusible(config: &ProtocolConfig) -> bool {
    config.chaos.is_none()
        && config.shaper.is_none()
        && config.worker_delays.is_empty()
        && config.link_delay.is_none()
}

/// Run `jobs` (same scheme, same shape) as one fused batch; `seeds[j]` is
/// job j's secret-stream seed, exactly as `ProtocolConfig::seed` would be
/// for a sequential run. Outputs come back in job order, each byte-identical
/// (Y, counters, traffic) to a sequential `run_job` with that seed.
pub fn run_fused_batch(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    jobs: &[(&FpMat, &FpMat)],
    seeds: &[u64],
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
) -> Result<Vec<ProtocolOutput>> {
    let k_jobs = jobs.len();
    if k_jobs == 0 {
        return Ok(Vec::new());
    }
    if seeds.len() != k_jobs {
        return Err(CmpcError::InvalidParams(format!(
            "fused batch has {k_jobs} jobs but {} seeds",
            seeds.len()
        )));
    }
    let p = scheme.params();
    let m = jobs[0].0.rows;
    for &(a, b) in jobs {
        validate_job_shapes(a, b, p)?;
        if a.rows != m {
            return Err(CmpcError::ShapeMismatch(format!(
                "fused batch requires same-shape jobs (got m={} and m={m})",
                a.rows
            )));
        }
    }
    let n = setup.n_workers;
    let t = p.t;
    let z = p.z;
    let t2 = t * t;
    let k_dim = t2 + z;
    let a_tol = config.adversary_tolerance.max(p.adversary_tolerance);
    let needed = k_dim + 2 * a_tol;
    if needed > n {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n,
        });
    }
    let alphas: &[u64] = &setup.alphas;

    // --- per-job counters (one set per job, as the fabric path registers) ---
    let t_setup = Instant::now();
    let counters: Vec<Vec<Arc<WorkerCounters>>> = (0..k_jobs)
        .map(|_| (0..n).map(|_| Arc::new(WorkerCounters::default())).collect())
        .collect();
    let setup_time = t_setup.elapsed();

    // --- Phase 1: share polynomials + wide encoding ---
    let t_p1 = Instant::now();
    // Legacy fork order per job: source A, source B (workers re-derive
    // their own forks from the same seed in Phase 2).
    let mut fa_polys = Vec::with_capacity(k_jobs);
    let mut fb_polys = Vec::with_capacity(k_jobs);
    for (j, &(a, b)) in jobs.iter().enumerate() {
        let mut job_rng = ChaChaRng::seed_from_u64(seeds[j]);
        let mut rng_src_a = job_rng.fork();
        let mut rng_src_b = job_rng.fork();
        fa_polys.push(source::build_f_a(scheme, a, &mut rng_src_a));
        fb_polys.push(source::build_f_b(scheme, b, &mut rng_src_b));
    }
    let fa0 = &fa_polys[0];
    let fb0 = &fb_polys[0];
    if cfg!(debug_assertions) {
        for poly in &fa_polys {
            debug_assert_eq!(poly.support(), fa0.support(), "shared-table contract");
        }
        for poly in &fb_polys {
            debug_assert_eq!(poly.support(), fb0.support(), "shared-table contract");
        }
    }
    // Per worker α: build each polynomial family's power table ONCE and
    // evaluate all k jobs through it — the batched form of
    // `source::encode_shares` (same kernel, k× fewer `ff::pow` chains).
    let shares: Vec<Vec<(FpMat, FpMat)>> = env.pool.par_map(alphas, |wid, _idx, &alpha| {
        env.scratch.with(wid, |s| {
            let mut fa_evals = Vec::with_capacity(k_jobs);
            fa0.power_table(alpha, &mut s.powers);
            for fa in &fa_polys {
                let mut out = FpMat::zeros(fa.rows, fa.cols);
                fa.eval_with_table(&s.powers, &mut out, &mut s.acc);
                fa_evals.push(out);
            }
            let mut fb_evals = Vec::with_capacity(k_jobs);
            fb0.power_table(alpha, &mut s.powers);
            for fb in &fb_polys {
                let mut out = FpMat::zeros(fb.rows, fb.cols);
                fb.eval_with_table(&s.powers, &mut out, &mut s.acc);
                fb_evals.push(out);
            }
            fa_evals.into_iter().zip(fb_evals).collect::<Vec<_>>()
        })
    });
    let fa_len = fa0.rows * fa0.cols;
    let fb_len = fb0.rows * fb0.cols;
    let phase1 = t_p1.elapsed();

    // --- Phase 2, stage A: per worker, wide H → scaled → masks → G ---
    let t_p2 = Instant::now();
    let len = (m / t) * (m / t); // one H / G / I block per job
    let wide_len = k_jobs * len;
    let stage_a: Result<Vec<Vec<Vec<u32>>>> = env
        .pool
        .par_map(&shares, |_wid, wn, pairs| -> Result<Vec<Vec<u32>>> {
            let mut backend = env.factory.make();
            // k per-job block products, stacked into one wide buffer.
            // (The product itself cannot fuse: F_A(αₙ) differs per job.)
            let mut wide_h: Vec<u32> = Vec::with_capacity(wide_len);
            for (fa_n, fb_n) in pairs {
                let h = backend.matmul_mod(fa_n, fb_n)?;
                debug_assert_eq!(h.len(), len, "H block shape");
                wide_h.extend_from_slice(&h.data);
            }
            // t² wide scaled copies: rₙ^{(i,l)} is per-worker, so one
            // scale of the concatenation scales every job's segment.
            let my_r = &setup.r_coeffs[wn];
            let scaled: Vec<Vec<u32>> = my_r
                .iter()
                .map(|&r| {
                    let mut sc = vec![0u32; wide_len];
                    ff::scale_into(&mut sc, r, &wide_h);
                    sc
                })
                .collect();
            // z wide masks: each job's segment comes from that job's own
            // secret stream (discard 2 + wn forks, take the next — the
            // exact stream `compute_phase` draws), masks in w-order.
            let mut masks: Vec<Vec<u32>> = vec![vec![0u32; wide_len]; z];
            for (j, &seed) in seeds.iter().enumerate() {
                let mut job_rng = ChaChaRng::seed_from_u64(seed);
                for _ in 0..2 + wn {
                    let _ = job_rng.fork();
                }
                let mut rng = job_rng.fork();
                for mask in masks.iter_mut() {
                    for v in mask[j * len..(j + 1) * len].iter_mut() {
                        *v = rng.field_element() as u32;
                    }
                }
            }
            // N wide G evaluations — one delayed-reduction pass per peer
            // over the t² + z wide coefficient buffers.
            let mut acc: Vec<u64> = Vec::new();
            let mut g_to: Vec<Vec<u32>> = Vec::with_capacity(n);
            for peer in 0..n {
                let alpha = alphas[peer];
                let mut terms: Vec<(u64, &[u32])> = Vec::with_capacity(t2 + z);
                let mut ap = 1u64;
                for sc in &scaled {
                    terms.push((ap, sc.as_slice()));
                    ap = ff::mul(ap, alpha);
                }
                for mask in &masks {
                    terms.push((ap, mask.as_slice()));
                    ap = ff::mul(ap, alpha);
                }
                let mut g = vec![0u32; wide_len];
                ff::weighted_sum_with_scratch(&mut g, &terms, &mut acc);
                g_to.push(g);
            }
            Ok(g_to)
        })
        .into_iter()
        .collect();
    let stage_a = stage_a?;

    // --- Phase 2, stage B: wide I(αₙ) = Σₙ' Gₙ'(αₙ) ---
    let worker_ids: Vec<usize> = (0..n).collect();
    let wide_i: Vec<Vec<u32>> = env.pool.par_map(&worker_ids, |wid, _idx, &wn| {
        env.scratch.with(wid, |s| {
            let terms: Vec<(u64, &[u32])> = stage_a
                .iter()
                .map(|g_to| (1u64, g_to[wn].as_slice()))
                .collect();
            let mut i_share = vec![0u32; wide_len];
            ff::weighted_sum_with_scratch(&mut i_share, &terms, &mut s.acc);
            i_share
        })
    });

    // Bulk-apply the sequential worker's exact per-job ξ/σ ticks
    // (`compute_phase` + the I accumulation/completion ticks).
    let h_mults = (fa0.rows * fa0.cols * fb0.cols) as u64;
    for job_counters in &counters {
        for c in job_counters {
            c.add_stored((fa_len + fb_len) as u64); // share pair intake
            c.add_mults(h_mults); // H = F_A·F_B
            c.add_stored(len as u64); // H resident
            c.add_mults((t2 * len) as u64); // t² scaled copies
            c.add_stored(t2 as u64); // Lagrange coefficients
            c.add_stored((z * len) as u64); // z masks
            c.add_mults((n * (t2 - 1 + z) * len) as u64); // N G evaluations
            c.add_stored((n * len) as u64); // N G evaluations resident
            c.add_stored(((n - 1) * len) as u64); // N−1 received G folds
            c.add_stored(len as u64); // final I share
        }
    }
    let phase2 = t_p2.elapsed();

    // --- Phase 3: one dense Vandermonde solve for the whole batch ---
    let t_p3 = Instant::now();
    let pts: Vec<u64> = alphas[..k_dim].to_vec();
    let support: Vec<u64> = (0..k_dim as u64).collect();
    let rows = try_vandermonde_inverse_rows(&pts, &support).ok_or_else(|| {
        CmpcError::NotDecodable(
            "singular dense Vandermonde during reconstruction (repeated αs?)".to_string(),
        )
    })?;
    let block = m / t;
    let mut flat: Vec<FpMat> = (0..k_jobs * t2)
        .map(|_| FpMat::zeros(block, block))
        .collect();
    env.pool.par_chunks_mut(&mut flat, 1, |wid, idx, blk| {
        let (j, e) = (idx / t2, idx % t2);
        env.scratch.with(wid, |s| {
            s.acc.clear();
            s.acc.resize(len, 0);
            for (n_idx, i_share) in wide_i.iter().take(k_dim).enumerate() {
                let c = rows[e][n_idx] % P;
                if c == 0 {
                    continue;
                }
                let seg = &i_share[j * len..(j + 1) * len];
                for (a, &x) in s.acc.iter_mut().zip(seg.iter()) {
                    *a += c * x as u64;
                }
            }
            ff::mont::fold(&mut blk[0].data, &s.acc, k_dim);
        });
    });
    // Reassemble each job's t×t grid: flat[j·t² + i + t·l] is job j's
    // block (i, l) — same layout as the master's sequential reassembly.
    let mut ys = Vec::with_capacity(k_jobs);
    let mut flat_iter = flat.into_iter();
    for _ in 0..k_jobs {
        let mut y_blocks: Vec<Vec<FpMat>> = (0..t).map(|_| Vec::with_capacity(t)).collect();
        for e in 0..t2 {
            let blk = flat_iter.next().expect("k·t² blocks");
            y_blocks[e % t].push(blk);
        }
        ys.push(FpMat::from_blocks(&y_blocks));
    }
    let reconstruct = t_p3.elapsed();

    // --- verification (same reference product as the sequential path) ---
    let verified = if config.verify {
        for (j, &(a, b)) in jobs.iter().enumerate() {
            let mut at = FpMat::zeros(a.cols, a.rows);
            a.transpose_into(&mut at);
            let mut expect = FpMat::zeros(at.rows, b.cols);
            at.par_matmul_into(b, &mut expect, env.pool, env.scratch);
            if ys[j] != expect {
                return Err(CmpcError::NotDecodable(format!(
                    "reconstruction mismatch: Y != AᵀB under {} (fused job {j})",
                    scheme.name()
                )));
            }
        }
        true
    } else {
        false
    };

    // --- per-job outputs: the scalars the fabric would have metered ---
    let traffic = TrafficReport {
        source_to_worker: (n * (fa_len + fb_len)) as u64,
        worker_to_worker: (n * (n - 1) * len) as u64,
        worker_to_master: (n * len) as u64,
        messages: (n * (n - 1) + 2 * n) as u64,
    };
    let timings = PhaseTimings {
        setup: setup_time,
        phase1_share: phase1,
        phase2_compute: phase2,
        phase3_reconstruct: reconstruct,
        ack_wait: Duration::ZERO,
    };
    Ok(counters
        .into_iter()
        .zip(ys)
        .map(|(job_counters, y)| ProtocolOutput {
            y,
            scheme_name: scheme.name(),
            n_workers: n,
            stragglers_tolerated: n - needed,
            timings,
            traffic,
            worker_counters: job_counters,
            verified,
            early_decoded: false,
            blamed_workers: Vec::new(),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::AgeCmpc;
    use crate::mpc::protocol::{prepare_setup, run_protocol_with_setup};
    use crate::runtime::{BackendFactory, ScratchPool, WorkerPool};

    fn env_parts(threads: usize) -> (Arc<BackendFactory>, Arc<WorkerPool>, ScratchPool) {
        let factory = Arc::new(BackendFactory::Native);
        let pool = WorkerPool::sized_or_global(threads);
        let scratch = ScratchPool::for_pool(&pool);
        (factory, pool, scratch)
    }

    fn random_jobs(k: usize, m: usize, seed: u64) -> Vec<(FpMat, FpMat)> {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        (0..k)
            .map(|_| (FpMat::random(&mut rng, m, m), FpMat::random(&mut rng, m, m)))
            .collect()
    }

    #[test]
    fn empty_batch_is_ok() {
        let scheme = AgeCmpc::new(2, 2, 1, 0);
        let setup = prepare_setup(&scheme).unwrap();
        let config = ProtocolConfig::default();
        let (factory, pool, scratch) = env_parts(2);
        let env = ExecEnv {
            factory: &factory,
            pool: &pool,
            scratch: &scratch,
        };
        let out = run_fused_batch(&scheme, &setup, &[], &[], &config, &env).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn seed_count_mismatch_is_rejected() {
        let scheme = AgeCmpc::new(2, 2, 1, 0);
        let setup = prepare_setup(&scheme).unwrap();
        let config = ProtocolConfig::default();
        let (factory, pool, scratch) = env_parts(2);
        let env = ExecEnv {
            factory: &factory,
            pool: &pool,
            scratch: &scratch,
        };
        let jobs = random_jobs(2, 4, 7);
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let err = run_fused_batch(&scheme, &setup, &refs, &[1], &config, &env).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }

    #[test]
    fn config_fusible_gates_fabric_knobs() {
        assert!(config_fusible(&ProtocolConfig::default()));
        let delayed = ProtocolConfig::builder()
            .link_delay(Some(Duration::from_millis(1)))
            .build();
        assert!(!config_fusible(&delayed));
        let skewed = ProtocolConfig::builder()
            .worker_delays(vec![Duration::ZERO; 4])
            .build();
        assert!(!config_fusible(&skewed));
    }

    /// The load-bearing identity: a fused batch must be observably the
    /// same as k sequential runs — Y, verified, per-worker ξ/σ counters,
    /// and the per-job traffic report, job by job.
    #[test]
    fn fused_batch_matches_sequential_runs() {
        let scheme = AgeCmpc::new(2, 2, 2, 1);
        let setup = prepare_setup(&scheme).unwrap();
        let jobs = random_jobs(3, 8, 42);
        let seeds = [9001u64, 9002, 9003];

        let mut config = ProtocolConfig::default();
        config.verify = true;
        config.threads = 2;
        let sequential: Vec<ProtocolOutput> = jobs
            .iter()
            .zip(seeds)
            .map(|((a, b), seed)| {
                let mut cfg = config.clone();
                cfg.seed = seed;
                run_protocol_with_setup(&scheme, &setup, a, b, &cfg).unwrap()
            })
            .collect();

        let (factory, pool, scratch) = env_parts(2);
        let env = ExecEnv {
            factory: &factory,
            pool: &pool,
            scratch: &scratch,
        };
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let fused = run_fused_batch(&scheme, &setup, &refs, &seeds, &config, &env).unwrap();

        assert_eq!(fused.len(), sequential.len());
        for (j, (f, s)) in fused.iter().zip(&sequential).enumerate() {
            assert_eq!(f.y, s.y, "job {j}: Y");
            assert!(f.verified, "job {j}: verified");
            assert_eq!(f.scheme_name, s.scheme_name, "job {j}: scheme");
            assert_eq!(f.n_workers, s.n_workers, "job {j}: n_workers");
            assert_eq!(
                f.stragglers_tolerated, s.stragglers_tolerated,
                "job {j}: stragglers"
            );
            assert_eq!(f.traffic, s.traffic, "job {j}: traffic");
            assert_eq!(f.worker_counters.len(), s.worker_counters.len());
            for (wn, (fc, sc)) in f
                .worker_counters
                .iter()
                .zip(&s.worker_counters)
                .enumerate()
            {
                assert_eq!(fc.mults(), sc.mults(), "job {j} worker {wn}: ξ");
                assert_eq!(fc.stored(), sc.stored(), "job {j} worker {wn}: σ");
            }
            assert!(!f.early_decoded);
            assert!(f.blamed_workers.is_empty());
        }
    }

    /// Fused outputs must not depend on the pool width (same determinism
    /// contract as the sequential encode/reconstruct kernels).
    #[test]
    fn fused_batch_is_pool_size_invariant() {
        let scheme = AgeCmpc::new(2, 2, 1, 0);
        let setup = prepare_setup(&scheme).unwrap();
        let jobs = random_jobs(4, 4, 5);
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let seeds = [11u64, 12, 13, 14];
        let config = ProtocolConfig::default();

        let mut ys: Vec<Vec<FpMat>> = Vec::new();
        for threads in [1usize, 4] {
            let (factory, pool, scratch) = env_parts(threads);
            let env = ExecEnv {
                factory: &factory,
                pool: &pool,
                scratch: &scratch,
            };
            let out = run_fused_batch(&scheme, &setup, &refs, &seeds, &config, &env).unwrap();
            ys.push(out.into_iter().map(|o| o.y).collect());
        }
        assert_eq!(ys[0], ys[1], "pool width changed fused outputs");
    }
}
