//! Phase 1 — sources build share polynomials and distribute evaluations.
//!
//! Source 1 holds `A` and shares `F_A(x) = C_A(x) + S_A(x)`; source 2 holds
//! `B` and shares `F_B(x)`. Coded coefficients are the `(s,t)`-partition
//! blocks placed at the scheme's coded powers; secret coefficients are fresh
//! uniform matrices at the scheme's secret powers. Each worker `n` receives
//! the pair `(F_A(αₙ), F_B(αₙ))`.

use std::sync::Arc;

use crate::codes::CmpcScheme;
use crate::matrix::FpMat;
use crate::mpc::network::{BufferPool, PooledMat};
use crate::poly::MatPoly;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::util::rng::ChaChaRng;

/// Build `F_A(x)` from `A` (the polynomial carries blocks of `Aᵀ`).
///
/// `A` must be `m×m` with `t|m` and `s|m`.
pub fn build_f_a(scheme: &dyn CmpcScheme, a: &FpMat, rng: &mut ChaChaRng) -> MatPoly {
    let p = scheme.params();
    let at = a.transpose();
    // (Aᵀ)_{i,j}: t row-parts, s col-parts → blocks of (m/t) × (m/s).
    let blocks = at.blocks(p.t, p.s);
    let (br, bc) = (blocks[0][0].rows, blocks[0][0].cols);
    let mut poly = MatPoly::new(br, bc);
    for (i, row) in blocks.into_iter().enumerate() {
        for (j, blk) in row.into_iter().enumerate() {
            poly.insert(scheme.coded_power_a(i, j), blk);
        }
    }
    for e in scheme.secret_powers_a() {
        poly.insert(e, FpMat::random(rng, br, bc));
    }
    poly
}

/// Build `F_B(x)` from `B`.
pub fn build_f_b(scheme: &dyn CmpcScheme, b: &FpMat, rng: &mut ChaChaRng) -> MatPoly {
    let p = scheme.params();
    // B_{k,l}: s row-parts, t col-parts → blocks of (m/s) × (m/t).
    let blocks = b.blocks(p.s, p.t);
    let (br, bc) = (blocks[0][0].rows, blocks[0][0].cols);
    let mut poly = MatPoly::new(br, bc);
    for (k, row) in blocks.into_iter().enumerate() {
        for (l, blk) in row.into_iter().enumerate() {
            poly.insert(scheme.coded_power_b(k, l), blk);
        }
    }
    for e in scheme.secret_powers_b() {
        poly.insert(e, FpMat::random(rng, br, bc));
    }
    poly
}

/// Evaluate a share polynomial at every worker's α.
pub fn shares(poly: &MatPoly, alphas: &[u64]) -> Vec<FpMat> {
    alphas.iter().map(|&a| poly.eval(a)).collect()
}

/// Evaluate both share polynomials at every worker's α, fanned out across
/// the pool — the Phase-1 encoding hot path.
///
/// Each pool worker evaluates whole `(F_A(αₙ), F_B(αₙ))` pairs through
/// [`MatPoly::eval_into`] with its own [`ScratchPool`] slot (power table +
/// unreduced accumulator), so the per-element loop performs no `ff::pow`
/// and the scratch buffers are reused across workers *and* across jobs.
/// Results come back in worker order, independent of the pool size — the
/// determinism tests pin `threads = 1` vs `N` byte-for-byte.
pub fn encode_shares(
    fa: &MatPoly,
    fb: &MatPoly,
    alphas: &[u64],
    pool: &WorkerPool,
    scratch: &ScratchPool,
) -> Vec<(FpMat, FpMat)> {
    pool.par_map(alphas, |wid, _idx, &alpha| {
        scratch.with(wid, |s| {
            let mut fa_n = FpMat::zeros(fa.rows, fa.cols);
            let mut fb_n = FpMat::zeros(fb.rows, fb.cols);
            fa.eval_into(alpha, &mut fa_n, s);
            fb.eval_into(alpha, &mut fb_n, s);
            (fa_n, fb_n)
        })
    })
}

/// [`encode_shares`], writing into payload buffers loaned from the fabric
/// [`BufferPool`] — the serving path. Evaluation is identical (same
/// [`MatPoly::eval_into`] kernel, same worker order), but the resulting
/// share pairs move straight into fabric envelopes and their buffers return
/// to the pool after the workers consume them, so a warm deployment encodes
/// Phase 1 with zero payload allocations.
pub fn encode_shares_pooled(
    fa: &MatPoly,
    fb: &MatPoly,
    alphas: &[u64],
    pool: &WorkerPool,
    scratch: &ScratchPool,
    bufs: &Arc<BufferPool>,
) -> Vec<(PooledMat, PooledMat)> {
    pool.par_map(alphas, |wid, _idx, &alpha| {
        scratch.with(wid, |s| {
            let mut fa_n = BufferPool::loan(bufs, fa.rows, fa.cols);
            let mut fb_n = BufferPool::loan(bufs, fb.rows, fb.cols);
            fa.eval_into(alpha, &mut fa_n, s);
            fb.eval_into(alpha, &mut fb_n, s);
            (fa_n, fb_n)
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::AgeCmpc;
    use crate::ff;

    #[test]
    fn f_a_carries_blocks_at_coded_powers() {
        let scheme = AgeCmpc::new(2, 2, 2, 2);
        let mut rng = ChaChaRng::seed_from_u64(1);
        let a = FpMat::random(&mut rng, 8, 8);
        let fa = build_f_a(&scheme, &a, &mut rng);
        let at_blocks = a.transpose().blocks(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(
                    fa.coeff(scheme.coded_power_a(i, j)).unwrap(),
                    &at_blocks[i][j]
                );
            }
        }
        assert_eq!(fa.num_terms(), 4 + 2); // st coded + z secret
    }

    #[test]
    fn product_of_shares_carries_y_blocks() {
        // The algebraic heart of the protocol: coefficient of H = F_A·F_B at
        // the important power (i,l) equals block (i,l) of AᵀB.
        let scheme = AgeCmpc::new(2, 3, 2, 1);
        let mut rng = ChaChaRng::seed_from_u64(5);
        let m = 6;
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let fa = build_f_a(&scheme, &a, &mut rng);
        let fb = build_f_b(&scheme, &b, &mut rng);
        let h = fa.mul_poly(&fb);
        let y = a.transpose().matmul(&b);
        let y_blocks = y.blocks(3, 3);
        for i in 0..3 {
            for l in 0..3 {
                assert_eq!(
                    h.coeff(scheme.important_power(i, l)).unwrap(),
                    &y_blocks[i][l],
                    "block ({i},{l})"
                );
            }
        }
    }

    #[test]
    fn encode_shares_matches_sequential_eval_at_any_pool_size() {
        let scheme = AgeCmpc::new(2, 2, 2, 1);
        let mut rng = ChaChaRng::seed_from_u64(23);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let fa = build_f_a(&scheme, &a, &mut rng);
        let fb = build_f_b(&scheme, &b, &mut rng);
        let alphas: Vec<u64> = (1..=9).collect();
        let want_a = shares(&fa, &alphas);
        let want_b = shares(&fb, &alphas);
        for threads in [1usize, 4] {
            let pool = WorkerPool::new(threads);
            let scratch = ScratchPool::for_pool(&pool);
            let got = encode_shares(&fa, &fb, &alphas, &pool, &scratch);
            assert_eq!(got.len(), alphas.len());
            for (n, (ga, gb)) in got.iter().enumerate() {
                assert_eq!(ga, &want_a[n], "F_A share {n} at {threads} threads");
                assert_eq!(gb, &want_b[n], "F_B share {n} at {threads} threads");
            }
        }
    }

    #[test]
    fn share_evaluation_is_consistent() {
        let scheme = AgeCmpc::new(2, 2, 1, 0);
        let mut rng = ChaChaRng::seed_from_u64(9);
        let a = FpMat::random(&mut rng, 4, 4);
        let fa = build_f_a(&scheme, &a, &mut rng);
        let alphas = vec![3, 7, 11];
        let sh = shares(&fa, &alphas);
        assert_eq!(sh.len(), 3);
        // F(α) = Σ coeff·α^e — spot check one entry against direct sum.
        let (r, c) = (0, 1);
        for (&alpha, share) in alphas.iter().zip(&sh) {
            let mut want = 0u64;
            for e in fa.support() {
                want = ff::add(
                    want,
                    ff::mul(fa.coeff(e).unwrap().at(r, c), ff::pow(alpha, e)),
                );
            }
            assert_eq!(share.at(r, c), want);
        }
    }
}
