//! Framed wire codec for fabric envelopes — std-only, versioned,
//! hardened against adversarial bytes.
//!
//! Every [`Envelope`] serializes to one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic      0x434D5043 ("CMPC"), little-endian
//! 4       2     version    WIRE_VERSION, little-endian
//! 6       8     job        JobId, little-endian
//! 14      4     from       sender NodeId, little-endian
//! 18      1     tag        payload kind
//! 19      4     len        payload byte length, little-endian
//! 23      len   payload
//! ```
//!
//! Matrices are `rows:u32, cols:u32` followed by `rows·cols` little-endian
//! `u32` scalars (all `< p`); control messages are a sub-tag byte plus a
//! fixed body ([`ControlMsg::JobError`] carries a length-prefixed UTF-8
//! string). The framing overhead on a Phase-2 `G`-share is
//! `HEADER_LEN + 8` bytes over the `4·(m/t)²` payload — under 5% for any
//! serving-sized block, which `tests/distributed.rs` pins against the
//! analytical ζ.
//!
//! **Decoding never trusts the peer.** Truncated buffers, flipped magic or
//! version, unknown tags, length prefixes that disagree with their
//! contents, matrix headers larger than their frame, and out-of-range
//! scalars all surface as typed [`CmpcError::Fabric`] errors — no panics,
//! and no allocation is sized from attacker-controlled fields before the
//! bytes backing it exist ([`FrameReader`] reads bodies in bounded
//! chunks, so a lying length prefix cannot trigger an outsized
//! allocation).
//!
//! One lossy corner, by construction: [`ControlMsg::JobStart`] carries a
//! shared-memory counters `Arc` that cannot cross a process boundary. The
//! codec serializes only the seed; the decoder installs a fresh counters
//! instance, and the worker's totals travel back in its
//! [`ControlMsg::JobDone`] / [`ControlMsg::AbortAck`].
//!
//! **Client plane.** The serving gateway's client-facing protocol shares
//! this frame header — the `job` slot carries the client's correlation id
//! and the `from` slot the tenant id — but uses a disjoint tag family
//! ([`ClientFrame`]): `Submit` / `Result` / `Reject` / `Shutdown`. The two
//! families are mutually unintelligible by construction: the fabric
//! decoder rejects client tags as unknown payloads and the client decoder
//! rejects fabric tags, so a client connection can never inject Phase-2
//! traffic into the worker fabric (and a misrouted worker socket cannot
//! impersonate a client). Client-plane decoding is incremental
//! ([`peek_client_header`] / [`decode_client_frame`]) so the gateway's
//! readiness poller can parse from partial nonblocking reads and reject
//! oversized submissions from the header alone, before buffering a body.

use std::io::Read;
use std::sync::Arc;

use crate::error::{CmpcError, Result};
use crate::ff::P;
use crate::matrix::FpMat;
use crate::metrics::WorkerCounters;
use crate::mpc::network::{BufferPool, ControlMsg, Envelope, Payload, PooledMat};

/// `"CMPC"` as a little-endian u32.
pub const WIRE_MAGIC: u32 = 0x434D_5043;

/// Current frame format version. Decoders reject every other version with
/// a typed error (no silent cross-version reads). v2 added the adversary
/// tolerance to `Submit` and the admin token to the client `Shutdown`;
/// v3 added the pipeline stage messages (`StageMask`/`StageMasked`
/// payloads and the `StageStart`/`StageShareZ`/`StageShareR` controls).
pub const WIRE_VERSION: u16 = 3;

/// Fixed frame header length in bytes.
pub const HEADER_LEN: usize = 23;

/// Upper bound on a single frame's payload (256 MiB) — rejects absurd
/// length prefixes before any allocation happens.
pub const MAX_FRAME_PAYLOAD: usize = 256 * 1024 * 1024;

/// Bodies are read from streams in chunks of this size, so a lying length
/// prefix allocates at most one chunk beyond the bytes actually received.
const READ_CHUNK: usize = 64 * 1024;

const TAG_SHARES: u8 = 0;
const TAG_SHARE_A: u8 = 1;
const TAG_SHARE_B: u8 = 2;
const TAG_GSHARE: u8 = 3;
const TAG_ISHARE: u8 = 4;
const TAG_CONTROL: u8 = 5;

// Client-plane tags (gateway front door). Disjoint from the fabric tags
// above so the two decoders reject each other's frames.
const TAG_SUBMIT: u8 = 6;
const TAG_RESULT: u8 = 7;
const TAG_REJECT: u8 = 8;
const TAG_GW_SHUTDOWN: u8 = 9;

// Pipeline stage payloads (wire v3).
const TAG_STAGE_MASK: u8 = 10;
const TAG_STAGE_MASKED: u8 = 11;

const CTL_JOB_START: u8 = 0;
const CTL_JOB_DONE: u8 = 1;
const CTL_JOB_ERROR: u8 = 2;
const CTL_JOB_ABORT: u8 = 3;
const CTL_ABORT_ACK: u8 = 4;
const CTL_SHUTDOWN: u8 = 5;
const CTL_JOB_INPUT: u8 = 6;
// Pipeline stage controls (wire v3).
const CTL_STAGE_START: u8 = 7;
const CTL_STAGE_SHARE_Z: u8 = 8;
const CTL_STAGE_SHARE_R: u8 = 9;

fn corrupt(msg: impl std::fmt::Display) -> CmpcError {
    CmpcError::Fabric(format!("wire: {msg}"))
}

// ---------------------------------------------------------------- encode

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

// `&PooledMat` deref-coerces to `&FpMat`, so both planes share these.
fn put_mat(out: &mut Vec<u8>, m: &FpMat) {
    put_u32(out, m.rows as u32);
    put_u32(out, m.cols as u32);
    for &v in &m.data {
        put_u32(out, v);
    }
}

fn mat_wire_len(m: &FpMat) -> usize {
    8 + 4 * m.len()
}

fn payload_tag(payload: &Payload) -> u8 {
    match payload {
        Payload::Shares { .. } => TAG_SHARES,
        Payload::ShareA(_) => TAG_SHARE_A,
        Payload::ShareB(_) => TAG_SHARE_B,
        Payload::GShare(_) => TAG_GSHARE,
        Payload::IShare(_) => TAG_ISHARE,
        Payload::StageMask { .. } => TAG_STAGE_MASK,
        Payload::StageMasked { .. } => TAG_STAGE_MASKED,
        Payload::Control(_) => TAG_CONTROL,
    }
}

fn payload_wire_len(payload: &Payload) -> usize {
    match payload {
        Payload::Shares { fa, fb } => mat_wire_len(fa) + mat_wire_len(fb),
        Payload::ShareA(m) | Payload::ShareB(m) => mat_wire_len(m),
        Payload::GShare(m) | Payload::IShare(m) => mat_wire_len(m),
        Payload::StageMask { mat, .. } | Payload::StageMasked { mat, .. } => {
            4 + mat_wire_len(mat)
        }
        Payload::Control(c) => {
            1 + match c {
                ControlMsg::JobStart { .. } => 8,
                ControlMsg::JobDone { .. } => 16,
                ControlMsg::JobError(msg) => 4 + msg.len(),
                ControlMsg::JobAbort => 0,
                ControlMsg::AbortAck { .. } => 16,
                ControlMsg::Shutdown => 0,
                ControlMsg::JobInput { mat, .. } => 8 + mat_wire_len(mat),
                ControlMsg::StageStart { .. } => 13,
                ControlMsg::StageShareZ { mat, .. } | ControlMsg::StageShareR { mat, .. } => {
                    4 + mat_wire_len(mat)
                }
            }
        }
    }
}

/// Exact on-wire size of `env`'s frame, header included — used by the
/// link shaper to model serialization time even on the in-process
/// transport, and by capacity planning.
pub fn frame_len(env: &Envelope) -> usize {
    HEADER_LEN + payload_wire_len(&env.payload)
}

/// Append `env`'s frame to `out` (which is **not** cleared — callers batch
/// frames by encoding into the same buffer).
pub fn encode_envelope(env: &Envelope, out: &mut Vec<u8>) {
    out.reserve(frame_len(env));
    put_u32(out, WIRE_MAGIC);
    put_u16(out, WIRE_VERSION);
    put_u64(out, env.job);
    put_u32(out, env.from as u32);
    out.push(payload_tag(&env.payload));
    put_u32(out, payload_wire_len(&env.payload) as u32);
    match &env.payload {
        Payload::Shares { fa, fb } => {
            put_mat(out, fa);
            put_mat(out, fb);
        }
        Payload::ShareA(m) | Payload::ShareB(m) => put_mat(out, m),
        Payload::GShare(m) | Payload::IShare(m) => put_mat(out, m),
        Payload::StageMask { stage, mat } | Payload::StageMasked { stage, mat } => {
            put_u32(out, *stage);
            put_mat(out, mat);
        }
        Payload::Control(c) => match c {
            ControlMsg::JobStart { seed, .. } => {
                out.push(CTL_JOB_START);
                put_u64(out, *seed);
            }
            ControlMsg::JobDone { mults, stored } => {
                out.push(CTL_JOB_DONE);
                put_u64(out, *mults);
                put_u64(out, *stored);
            }
            ControlMsg::JobError(msg) => {
                out.push(CTL_JOB_ERROR);
                put_u32(out, msg.len() as u32);
                out.extend_from_slice(msg.as_bytes());
            }
            ControlMsg::JobAbort => out.push(CTL_JOB_ABORT),
            ControlMsg::AbortAck { mults, stored } => {
                out.push(CTL_ABORT_ACK);
                put_u64(out, *mults);
                put_u64(out, *stored);
            }
            ControlMsg::Shutdown => out.push(CTL_SHUTDOWN),
            ControlMsg::JobInput { seed, mat } => {
                out.push(CTL_JOB_INPUT);
                put_u64(out, *seed);
                put_mat(out, mat);
            }
            // Like JobStart, the counters Arc is process-local shared
            // memory: only the stage/seed/masked flag travel, and the
            // remote worker installs a fresh counter instance.
            ControlMsg::StageStart {
                stage,
                seed,
                masked,
                ..
            } => {
                out.push(CTL_STAGE_START);
                put_u32(out, *stage);
                put_u64(out, *seed);
                out.push(u8::from(*masked));
            }
            ControlMsg::StageShareZ { stage, mat } => {
                out.push(CTL_STAGE_SHARE_Z);
                put_u32(out, *stage);
                put_mat(out, mat);
            }
            ControlMsg::StageShareR { stage, mat } => {
                out.push(CTL_STAGE_SHARE_R);
                put_u32(out, *stage);
                put_mat(out, mat);
            }
        },
    }
}

/// Encode `env` into `scratch` (cleared first) and write it to `w`.
/// Returns the frame length in bytes.
///
/// Payloads over [`MAX_FRAME_PAYLOAD`] are rejected **here, at the
/// sender** with a typed error: encoding them would produce a frame every
/// receiver discards as oversized (and past `u32::MAX` the length prefix
/// would wrap, mis-framing the whole stream), turning a loud local
/// failure into a silent remote wedge.
pub fn write_envelope<W: std::io::Write>(
    w: &mut W,
    env: &Envelope,
    scratch: &mut Vec<u8>,
) -> Result<usize> {
    let payload_len = payload_wire_len(&env.payload);
    if payload_len > MAX_FRAME_PAYLOAD {
        return Err(CmpcError::Fabric(format!(
            "wire: refusing to send a {payload_len}-byte payload \
             (cap {MAX_FRAME_PAYLOAD} bytes; partition the job smaller)"
        )));
    }
    scratch.clear();
    encode_envelope(env, scratch);
    w.write_all(scratch)
        .map_err(|e| CmpcError::Fabric(format!("wire write: {e}")))?;
    Ok(scratch.len())
}

// ---------------------------------------------------------------- decode

/// Bounds-checked little-endian cursor over untrusted bytes.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(corrupt(format!(
                "truncated frame: wanted {n} more bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }
}

struct FrameHeader {
    job: u64,
    from: usize,
    tag: u8,
    len: usize,
}

fn parse_header(r: &mut Reader<'_>) -> Result<FrameHeader> {
    let magic = r.u32()?;
    if magic != WIRE_MAGIC {
        return Err(corrupt(format!(
            "bad magic 0x{magic:08x} (expected 0x{WIRE_MAGIC:08x})"
        )));
    }
    let version = r.u16()?;
    if version != WIRE_VERSION {
        return Err(corrupt(format!(
            "version mismatch: frame is v{version}, this build speaks v{WIRE_VERSION}"
        )));
    }
    let job = r.u64()?;
    let from = r.u32()? as usize;
    let tag = r.u8()?;
    let len = r.u32()? as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(corrupt(format!(
            "oversized frame: payload length {len} exceeds the {MAX_FRAME_PAYLOAD}-byte cap"
        )));
    }
    Ok(FrameHeader {
        job,
        from,
        tag,
        len,
    })
}

fn decode_mat(r: &mut Reader<'_>, bufs: Option<&Arc<BufferPool>>) -> Result<PooledMat> {
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let scalars = (rows as u64).saturating_mul(cols as u64);
    // Reject before allocating: the matrix must fit in the bytes that are
    // actually present.
    if scalars.saturating_mul(4) > r.remaining() as u64 {
        return Err(corrupt(format!(
            "matrix header claims {rows}x{cols} scalars but only {} payload bytes remain",
            r.remaining()
        )));
    }
    let scalars = scalars as usize;
    let mut mat = match bufs {
        Some(pool) => BufferPool::loan(pool, rows, cols),
        None => PooledMat::detached(crate::matrix::FpMat::zeros(rows, cols)),
    };
    for slot in mat.data.iter_mut().take(scalars) {
        let v = r.u32()?;
        if (v as u64) >= P {
            return Err(corrupt(format!("scalar {v} out of field range (p = {P})")));
        }
        *slot = v;
    }
    Ok(mat)
}

/// Same validation as [`decode_mat`] but into a plain (unpooled) [`FpMat`]
/// — the client plane and [`ControlMsg::JobInput`] carry whole input
/// matrices whose lifetime is the job, not a fabric receive buffer.
fn decode_fpmat(r: &mut Reader<'_>) -> Result<FpMat> {
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let scalars = (rows as u64).saturating_mul(cols as u64);
    if scalars.saturating_mul(4) > r.remaining() as u64 {
        return Err(corrupt(format!(
            "matrix header claims {rows}x{cols} scalars but only {} payload bytes remain",
            r.remaining()
        )));
    }
    let mut mat = FpMat::zeros(rows, cols);
    for slot in mat.data.iter_mut() {
        let v = r.u32()?;
        if (v as u64) >= P {
            return Err(corrupt(format!("scalar {v} out of field range (p = {P})")));
        }
        *slot = v;
    }
    Ok(mat)
}

fn decode_payload(tag: u8, body: &[u8], bufs: Option<&Arc<BufferPool>>) -> Result<Payload> {
    let mut r = Reader::new(body);
    let payload = match tag {
        TAG_SHARES => {
            let fa = decode_mat(&mut r, bufs)?;
            let fb = decode_mat(&mut r, bufs)?;
            Payload::Shares { fa, fb }
        }
        TAG_SHARE_A => Payload::ShareA(decode_mat(&mut r, bufs)?),
        TAG_SHARE_B => Payload::ShareB(decode_mat(&mut r, bufs)?),
        TAG_GSHARE => Payload::GShare(decode_mat(&mut r, bufs)?),
        TAG_ISHARE => Payload::IShare(decode_mat(&mut r, bufs)?),
        TAG_STAGE_MASK => Payload::StageMask {
            stage: r.u32()?,
            mat: decode_mat(&mut r, bufs)?,
        },
        TAG_STAGE_MASKED => Payload::StageMasked {
            stage: r.u32()?,
            mat: decode_mat(&mut r, bufs)?,
        },
        TAG_CONTROL => {
            let ctl = match r.u8()? {
                CTL_JOB_START => ControlMsg::JobStart {
                    seed: r.u64()?,
                    // The counters Arc cannot cross a wire; the receiver
                    // gets a fresh instance and reports totals back in its
                    // JobDone / AbortAck.
                    counters: Arc::new(WorkerCounters::default()),
                },
                CTL_JOB_DONE => ControlMsg::JobDone {
                    mults: r.u64()?,
                    stored: r.u64()?,
                },
                CTL_JOB_ERROR => {
                    let len = r.u32()? as usize;
                    let bytes = r.bytes(len)?;
                    ControlMsg::JobError(String::from_utf8_lossy(bytes).into_owned())
                }
                CTL_JOB_ABORT => ControlMsg::JobAbort,
                CTL_ABORT_ACK => ControlMsg::AbortAck {
                    mults: r.u64()?,
                    stored: r.u64()?,
                },
                CTL_SHUTDOWN => ControlMsg::Shutdown,
                CTL_JOB_INPUT => ControlMsg::JobInput {
                    seed: r.u64()?,
                    mat: decode_fpmat(&mut r)?,
                },
                CTL_STAGE_START => ControlMsg::StageStart {
                    stage: r.u32()?,
                    seed: r.u64()?,
                    masked: r.u8()? != 0,
                    // Fresh local instance, as for JobStart.
                    counters: Arc::new(WorkerCounters::default()),
                },
                CTL_STAGE_SHARE_Z => ControlMsg::StageShareZ {
                    stage: r.u32()?,
                    mat: decode_fpmat(&mut r)?,
                },
                CTL_STAGE_SHARE_R => ControlMsg::StageShareR {
                    stage: r.u32()?,
                    mat: decode_fpmat(&mut r)?,
                },
                other => return Err(corrupt(format!("unknown control sub-tag {other}"))),
            };
            Payload::Control(ctl)
        }
        other => return Err(corrupt(format!("unknown payload tag {other}"))),
    };
    if r.remaining() != 0 {
        return Err(corrupt(format!(
            "frame length mismatch: {} trailing payload bytes",
            r.remaining()
        )));
    }
    Ok(payload)
}

/// Decode one frame from the front of `buf`. Returns the envelope and the
/// number of bytes consumed. Matrices are loaned from `bufs` when given
/// (the zero-alloc receive path), detached otherwise.
pub fn decode_envelope(
    buf: &[u8],
    bufs: Option<&Arc<BufferPool>>,
) -> Result<(Envelope, usize)> {
    let mut r = Reader::new(buf);
    let h = parse_header(&mut r)?;
    let body = r.bytes(h.len)?;
    let payload = decode_payload(h.tag, body, bufs)?;
    Ok((
        Envelope {
            job: h.job,
            from: h.from,
            payload,
        },
        HEADER_LEN + h.len,
    ))
}

/// Streaming frame decoder with a reusable body buffer (one per reader
/// thread: steady-state frames reuse its capacity, and pooled matrices
/// make the whole receive path allocation-free once warm).
#[derive(Default)]
pub struct FrameReader {
    body: Vec<u8>,
}

impl FrameReader {
    /// A fresh reader with an empty body buffer.
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Read exactly one frame from `r`. `Ok(None)` on a clean EOF at a
    /// frame boundary (the peer closed); mid-frame EOF, I/O failures, and
    /// corrupt frames are typed errors.
    pub fn read_from<R: Read>(
        &mut self,
        r: &mut R,
        bufs: Option<&Arc<BufferPool>>,
    ) -> Result<Option<Envelope>> {
        let mut header = [0u8; HEADER_LEN];
        let mut got = 0usize;
        while got < HEADER_LEN {
            match r.read(&mut header[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    return Err(corrupt(format!(
                        "connection closed {got} bytes into a frame header"
                    )));
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(CmpcError::Fabric(format!("wire read: {e}"))),
            }
        }
        let h = parse_header(&mut Reader::new(&header))?;
        self.body.clear();
        // Chunked body read: a lying length prefix can make us allocate at
        // most one READ_CHUNK beyond what the peer actually sent.
        let mut remaining = h.len;
        while remaining > 0 {
            let chunk = remaining.min(READ_CHUNK);
            let start = self.body.len();
            self.body.resize(start + chunk, 0);
            r.read_exact(&mut self.body[start..]).map_err(|e| {
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    corrupt(format!(
                        "connection closed mid-frame ({} of {} payload bytes missing)",
                        remaining,
                        h.len
                    ))
                } else {
                    CmpcError::Fabric(format!("wire read: {e}"))
                }
            })?;
            remaining -= chunk;
        }
        let payload = decode_payload(h.tag, &self.body, bufs)?;
        Ok(Some(Envelope {
            job: h.job,
            from: h.from,
            payload,
        }))
    }
}

// ---------------------------------------------------------- client plane

/// Why a gateway refused a submission — carried verbatim in a
/// [`ClientMsg::Reject`] so clients and tests branch on the cause without
/// parsing prose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The tenant's token bucket is empty (rate/burst quota).
    QuotaExceeded,
    /// The tenant's pending-job queue is at its depth cap.
    QueueFull,
    /// The tenant id is not in the gateway's manifest.
    UnknownTenant,
    /// The submission failed scheme/shape validation.
    Malformed,
    /// The frame's payload exceeds the gateway's configured cap.
    TooLarge,
    /// The gateway is draining for shutdown.
    ShuttingDown,
    /// The deployment failed after admission (the one post-door reason).
    Internal,
    /// A [`ClientMsg::Shutdown`] carried the wrong admin token.
    Unauthorized,
}

impl RejectReason {
    /// Stable wire code — also the index into
    /// [`crate::metrics::GatewayStats::rejected`].
    pub fn as_u8(self) -> u8 {
        match self {
            RejectReason::QuotaExceeded => 0,
            RejectReason::QueueFull => 1,
            RejectReason::UnknownTenant => 2,
            RejectReason::Malformed => 3,
            RejectReason::TooLarge => 4,
            RejectReason::ShuttingDown => 5,
            RejectReason::Internal => 6,
            RejectReason::Unauthorized => 7,
        }
    }

    fn from_u8(v: u8) -> Option<RejectReason> {
        Some(match v {
            0 => RejectReason::QuotaExceeded,
            1 => RejectReason::QueueFull,
            2 => RejectReason::UnknownTenant,
            3 => RejectReason::Malformed,
            4 => RejectReason::TooLarge,
            5 => RejectReason::ShuttingDown,
            6 => RejectReason::Internal,
            7 => RejectReason::Unauthorized,
            _ => return None,
        })
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RejectReason::QuotaExceeded => "quota-exceeded",
            RejectReason::QueueFull => "queue-full",
            RejectReason::UnknownTenant => "unknown-tenant",
            RejectReason::Malformed => "malformed",
            RejectReason::TooLarge => "too-large",
            RejectReason::ShuttingDown => "shutting-down",
            RejectReason::Internal => "internal",
            RejectReason::Unauthorized => "unauthorized",
        })
    }
}

/// Client-plane payloads (tags 6–9).
#[derive(Debug, Clone)]
pub enum ClientMsg {
    /// A tenant submits one `Y = AᵀB` job under scheme params `(s, t, z)`
    /// plus the adversary tolerance `adv` the decode must honor (raises
    /// the recovery quota to `t² + z + 2·adv`).
    Submit {
        /// Row partition factor.
        s: usize,
        /// Column partition factor.
        t: usize,
        /// Collusion tolerance.
        z: usize,
        /// Adversary (Byzantine) tolerance the decode must honor.
        adv: usize,
        /// The client's `A` matrix.
        a: FpMat,
        /// The client's `B` matrix.
        b: FpMat,
    },
    /// Success: the decoded product, its FNV digest, and the serving
    /// latency the gateway observed (admission → decode).
    Result {
        /// FNV digest of `y` (what CI diffs against the reference).
        digest: u64,
        /// Admission→decode latency in microseconds.
        elapsed_us: u64,
        /// The decoded product.
        y: FpMat,
    },
    /// Typed refusal. Every reason except [`RejectReason::Internal`] is
    /// decided at the door, before the job touches a deployment.
    Reject {
        /// The typed cause.
        reason: RejectReason,
        /// Free-form human-readable context.
        detail: String,
    },
    /// Administrative: drain in-flight jobs and stop the gateway (the CI
    /// lane's clean teardown). `token` must match the gateway's
    /// `gateway_token` manifest line; a mismatch is answered with a
    /// [`RejectReason::Unauthorized`] and the gateway keeps serving. A
    /// gateway with no configured token accepts any value (the
    /// pre-auth behavior, for single-operator rigs).
    Shutdown {
        /// Must match the gateway's `gateway_token` manifest line.
        token: u64,
    },
}

/// One client-plane frame. Shares the fabric's 23-byte header: the `job`
/// slot carries the client's correlation id (echoed verbatim on the
/// response) and the `from` slot the tenant id.
#[derive(Debug, Clone)]
pub struct ClientFrame {
    /// Correlation id, echoed verbatim on the response.
    pub corr: u64,
    /// Tenant id of the submitting client.
    pub tenant: u32,
    /// The client-plane payload.
    pub msg: ClientMsg,
}

fn client_tag(msg: &ClientMsg) -> u8 {
    match msg {
        ClientMsg::Submit { .. } => TAG_SUBMIT,
        ClientMsg::Result { .. } => TAG_RESULT,
        ClientMsg::Reject { .. } => TAG_REJECT,
        ClientMsg::Shutdown { .. } => TAG_GW_SHUTDOWN,
    }
}

fn client_payload_len(msg: &ClientMsg) -> usize {
    match msg {
        ClientMsg::Submit { a, b, .. } => 16 + mat_wire_len(a) + mat_wire_len(b),
        ClientMsg::Result { y, .. } => 16 + mat_wire_len(y),
        ClientMsg::Reject { detail, .. } => 5 + detail.len(),
        ClientMsg::Shutdown { .. } => 8,
    }
}

/// Exact on-wire size of `frame`, header included.
pub fn client_frame_len(frame: &ClientFrame) -> usize {
    HEADER_LEN + client_payload_len(&frame.msg)
}

/// Append `frame`'s bytes to `out` (not cleared — callers batch frames).
pub fn encode_client_frame(frame: &ClientFrame, out: &mut Vec<u8>) {
    out.reserve(client_frame_len(frame));
    put_u32(out, WIRE_MAGIC);
    put_u16(out, WIRE_VERSION);
    put_u64(out, frame.corr);
    put_u32(out, frame.tenant);
    out.push(client_tag(&frame.msg));
    put_u32(out, client_payload_len(&frame.msg) as u32);
    match &frame.msg {
        ClientMsg::Submit { s, t, z, adv, a, b } => {
            put_u32(out, *s as u32);
            put_u32(out, *t as u32);
            put_u32(out, *z as u32);
            put_u32(out, *adv as u32);
            put_mat(out, a);
            put_mat(out, b);
        }
        ClientMsg::Result {
            digest,
            elapsed_us,
            y,
        } => {
            put_u64(out, *digest);
            put_u64(out, *elapsed_us);
            put_mat(out, y);
        }
        ClientMsg::Reject { reason, detail } => {
            out.push(reason.as_u8());
            put_u32(out, detail.len() as u32);
            out.extend_from_slice(detail.as_bytes());
        }
        ClientMsg::Shutdown { token } => put_u64(out, *token),
    }
}

/// Encode `frame` into `scratch` (cleared) and write it to `w`, with the
/// same sender-side payload cap as [`write_envelope`].
pub fn write_client_frame<W: std::io::Write>(
    w: &mut W,
    frame: &ClientFrame,
    scratch: &mut Vec<u8>,
) -> Result<usize> {
    let payload_len = client_payload_len(&frame.msg);
    if payload_len > MAX_FRAME_PAYLOAD {
        return Err(CmpcError::Fabric(format!(
            "wire: refusing to send a {payload_len}-byte client payload \
             (cap {MAX_FRAME_PAYLOAD} bytes; partition the job smaller)"
        )));
    }
    scratch.clear();
    encode_client_frame(frame, scratch);
    w.write_all(scratch)
        .map_err(|e| CmpcError::Fabric(format!("wire write: {e}")))?;
    Ok(scratch.len())
}

/// A validated client-frame header — what the gateway's poller learns
/// from the first [`HEADER_LEN`] buffered bytes, before any body arrives.
#[derive(Debug, Clone, Copy)]
pub struct ClientHeader {
    /// Correlation id (the fabric header's `job` slot).
    pub corr: u64,
    /// Tenant id (the fabric header's `from` slot).
    pub tenant: u32,
    /// Message tag (one of the client-plane tags 6–9).
    pub tag: u8,
    /// Declared body length, already validated against the frame cap.
    pub payload_len: usize,
}

/// Validate and parse a client-frame header from the front of `buf`.
/// `Ok(None)` while fewer than [`HEADER_LEN`] bytes are buffered; flipped
/// magic/version and oversized length prefixes are typed errors. This is
/// how the poller rejects an oversized submission from 23 bytes, without
/// ever buffering the claimed body.
pub fn peek_client_header(buf: &[u8]) -> Result<Option<ClientHeader>> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    let h = parse_header(&mut Reader::new(&buf[..HEADER_LEN]))?;
    Ok(Some(ClientHeader {
        corr: h.job,
        tenant: h.from as u32,
        tag: h.tag,
        payload_len: h.len,
    }))
}

/// Decode one client frame from the front of `buf`. `Ok(None)` while the
/// buffer holds less than a full frame (keep reading); `Ok(Some((frame,
/// consumed)))` once one is complete; corrupt bytes are typed errors.
/// Fabric tags (0–5) are rejected here — the planes never cross.
pub fn decode_client_frame(buf: &[u8]) -> Result<Option<(ClientFrame, usize)>> {
    let h = match peek_client_header(buf)? {
        Some(h) => h,
        None => return Ok(None),
    };
    let total = HEADER_LEN + h.payload_len;
    if buf.len() < total {
        return Ok(None);
    }
    let msg = decode_client_payload(h.tag, &buf[HEADER_LEN..total])?;
    Ok(Some((
        ClientFrame {
            corr: h.corr,
            tenant: h.tenant,
            msg,
        },
        total,
    )))
}

fn decode_client_payload(tag: u8, body: &[u8]) -> Result<ClientMsg> {
    let mut r = Reader::new(body);
    let msg = match tag {
        TAG_SUBMIT => {
            let s = r.u32()? as usize;
            let t = r.u32()? as usize;
            let z = r.u32()? as usize;
            let adv = r.u32()? as usize;
            let a = decode_fpmat(&mut r)?;
            let b = decode_fpmat(&mut r)?;
            ClientMsg::Submit { s, t, z, adv, a, b }
        }
        TAG_RESULT => ClientMsg::Result {
            digest: r.u64()?,
            elapsed_us: r.u64()?,
            y: decode_fpmat(&mut r)?,
        },
        TAG_REJECT => {
            let code = r.u8()?;
            let reason = RejectReason::from_u8(code)
                .ok_or_else(|| corrupt(format!("unknown reject reason {code}")))?;
            let len = r.u32()? as usize;
            let bytes = r.bytes(len)?;
            ClientMsg::Reject {
                reason,
                detail: String::from_utf8_lossy(bytes).into_owned(),
            }
        }
        TAG_GW_SHUTDOWN => ClientMsg::Shutdown { token: r.u64()? },
        other => return Err(corrupt(format!("unknown client frame tag {other}"))),
    };
    if r.remaining() != 0 {
        return Err(corrupt(format!(
            "client frame length mismatch: {} trailing payload bytes",
            r.remaining()
        )));
    }
    Ok(msg)
}

/// Blocking read of one client frame from `r` — the load driver's receive
/// path (the gateway itself parses incrementally via
/// [`decode_client_frame`]). `Ok(None)` on a clean EOF at a frame
/// boundary; bodies are read in bounded chunks like [`FrameReader`].
pub fn read_client_frame<R: Read>(r: &mut R) -> Result<Option<ClientFrame>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(corrupt(format!(
                    "connection closed {got} bytes into a frame header"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(CmpcError::Fabric(format!("wire read: {e}"))),
        }
    }
    let h = parse_header(&mut Reader::new(&header))?;
    let mut body = Vec::new();
    let mut remaining = h.len;
    while remaining > 0 {
        let chunk = remaining.min(READ_CHUNK);
        let start = body.len();
        body.resize(start + chunk, 0);
        r.read_exact(&mut body[start..]).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                corrupt(format!(
                    "connection closed mid-frame ({remaining} of {} payload bytes missing)",
                    h.len
                ))
            } else {
                CmpcError::Fabric(format!("wire read: {e}"))
            }
        })?;
        remaining -= chunk;
    }
    let msg = decode_client_payload(h.tag, &body)?;
    Ok(Some(ClientFrame {
        corr: h.job,
        tenant: h.from as u32,
        msg,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::FpMat;
    use crate::util::rng::ChaChaRng;

    fn mat(rows: usize, cols: usize, seed: u64) -> PooledMat {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        PooledMat::detached(FpMat::random(&mut rng, rows, cols))
    }

    fn fpmat(rows: usize, cols: usize, seed: u64) -> FpMat {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        FpMat::random(&mut rng, rows, cols)
    }

    fn env(payload: Payload) -> Envelope {
        Envelope {
            job: 0x0123_4567_89AB_CDEF,
            from: 42,
            payload,
        }
    }

    fn every_payload() -> Vec<Payload> {
        vec![
            Payload::Shares {
                fa: mat(3, 4, 1),
                fb: mat(4, 2, 2),
            },
            Payload::ShareA(mat(2, 2, 3)),
            Payload::ShareB(mat(1, 5, 4)),
            Payload::GShare(mat(4, 4, 5)),
            Payload::IShare(mat(0, 0, 6)), // empty matrices are legal
            Payload::Control(ControlMsg::JobStart {
                seed: 77,
                counters: Arc::new(WorkerCounters::default()),
            }),
            Payload::Control(ControlMsg::JobDone {
                mults: 123,
                stored: 456,
            }),
            Payload::Control(ControlMsg::JobError("worker 3: α went missing".into())),
            Payload::Control(ControlMsg::JobAbort),
            Payload::Control(ControlMsg::AbortAck {
                mults: 9,
                stored: 10,
            }),
            Payload::Control(ControlMsg::Shutdown),
            Payload::Control(ControlMsg::JobInput {
                seed: 0xBEEF,
                mat: fpmat(3, 3, 11),
            }),
            Payload::StageMask {
                stage: 2,
                mat: mat(2, 2, 12),
            },
            Payload::StageMasked {
                stage: 3,
                mat: mat(0, 0, 13), // empty matrices are legal here too
            },
            Payload::Control(ControlMsg::StageStart {
                stage: 4,
                seed: 0xF00D,
                masked: true,
                counters: Arc::new(WorkerCounters::default()),
            }),
            Payload::Control(ControlMsg::StageShareZ {
                stage: 5,
                mat: fpmat(2, 3, 14),
            }),
            Payload::Control(ControlMsg::StageShareR {
                stage: 6,
                mat: fpmat(3, 2, 15),
            }),
        ]
    }

    fn assert_payload_eq(a: &Payload, b: &Payload) {
        match (a, b) {
            (Payload::Shares { fa, fb }, Payload::Shares { fa: fa2, fb: fb2 }) => {
                assert_eq!(**fa, **fa2);
                assert_eq!(**fb, **fb2);
            }
            (Payload::ShareA(x), Payload::ShareA(y))
            | (Payload::ShareB(x), Payload::ShareB(y))
            | (Payload::GShare(x), Payload::GShare(y))
            | (Payload::IShare(x), Payload::IShare(y)) => assert_eq!(**x, **y),
            (
                Payload::StageMask { stage, mat },
                Payload::StageMask { stage: s2, mat: m2 },
            )
            | (
                Payload::StageMasked { stage, mat },
                Payload::StageMasked { stage: s2, mat: m2 },
            ) => {
                assert_eq!(stage, s2);
                assert_eq!(**mat, **m2);
            }
            (Payload::Control(x), Payload::Control(y)) => match (x, y) {
                (
                    ControlMsg::JobStart { seed, .. },
                    ControlMsg::JobStart { seed: s2, .. },
                ) => assert_eq!(seed, s2),
                (
                    ControlMsg::JobDone { mults, stored },
                    ControlMsg::JobDone {
                        mults: m2,
                        stored: s2,
                    },
                )
                | (
                    ControlMsg::AbortAck { mults, stored },
                    ControlMsg::AbortAck {
                        mults: m2,
                        stored: s2,
                    },
                ) => {
                    assert_eq!(mults, m2);
                    assert_eq!(stored, s2);
                }
                (ControlMsg::JobError(m1), ControlMsg::JobError(m2)) => assert_eq!(m1, m2),
                (ControlMsg::JobAbort, ControlMsg::JobAbort) => {}
                (ControlMsg::Shutdown, ControlMsg::Shutdown) => {}
                (
                    ControlMsg::JobInput { seed, mat },
                    ControlMsg::JobInput { seed: s2, mat: m2 },
                ) => {
                    assert_eq!(seed, s2);
                    assert_eq!(mat, m2);
                }
                (
                    ControlMsg::StageStart {
                        stage,
                        seed,
                        masked,
                        ..
                    },
                    ControlMsg::StageStart {
                        stage: st2,
                        seed: s2,
                        masked: mk2,
                        ..
                    },
                ) => {
                    assert_eq!(stage, st2);
                    assert_eq!(seed, s2);
                    assert_eq!(masked, mk2);
                }
                (
                    ControlMsg::StageShareZ { stage, mat },
                    ControlMsg::StageShareZ { stage: s2, mat: m2 },
                )
                | (
                    ControlMsg::StageShareR { stage, mat },
                    ControlMsg::StageShareR { stage: s2, mat: m2 },
                ) => {
                    assert_eq!(stage, s2);
                    assert_eq!(mat, m2);
                }
                (x, y) => panic!("control variant mismatch: {x:?} vs {y:?}"),
            },
            (a, b) => panic!("payload variant mismatch: {a:?} vs {b:?}"),
        }
    }

    #[test]
    fn roundtrip_every_variant() {
        for payload in every_payload() {
            let e = env(payload);
            let mut buf = Vec::new();
            encode_envelope(&e, &mut buf);
            assert_eq!(buf.len(), frame_len(&e), "frame_len disagrees for {e:?}");
            let (back, consumed) = decode_envelope(&buf, None).unwrap();
            assert_eq!(consumed, buf.len());
            assert_eq!(back.job, e.job);
            assert_eq!(back.from, e.from);
            assert_payload_eq(&back.payload, &e.payload);
        }
    }

    #[test]
    fn roundtrip_through_a_stream_with_pooled_buffers() {
        let pool = BufferPool::new();
        let mut buf = Vec::new();
        let frames = every_payload();
        let count = frames.len();
        for payload in frames {
            encode_envelope(&env(payload), &mut buf);
        }
        let mut cursor = std::io::Cursor::new(buf);
        let mut fr = FrameReader::new();
        let mut seen = 0;
        while let Some(e) = fr.read_from(&mut cursor, Some(&pool)).unwrap() {
            assert_eq!(e.from, 42);
            seen += 1;
        }
        assert_eq!(seen, count);
        // EOF at a frame boundary keeps returning None
        assert!(fr.read_from(&mut cursor, Some(&pool)).unwrap().is_none());
        // decoded matrices were loaned from the pool and returned on drop
        assert!(pool.free_buffers() > 0);
    }

    #[test]
    fn truncation_at_every_boundary_is_a_typed_error() {
        for payload in every_payload() {
            let e = env(payload);
            let mut buf = Vec::new();
            encode_envelope(&e, &mut buf);
            for cut in 0..buf.len() {
                let err = decode_envelope(&buf[..cut], None).unwrap_err();
                assert!(
                    matches!(err, CmpcError::Fabric(_)),
                    "cut at {cut}: {err}"
                );
                // streaming: EOF mid-frame is an error, EOF at 0 is None
                let mut cursor = std::io::Cursor::new(buf[..cut].to_vec());
                let got = FrameReader::new().read_from(&mut cursor, None);
                if cut == 0 {
                    assert!(matches!(got, Ok(None)));
                } else {
                    assert!(got.is_err(), "stream cut at {cut} did not error");
                }
            }
        }
    }

    #[test]
    fn corrupt_headers_are_typed_errors() {
        let e = env(Payload::GShare(mat(2, 2, 9)));
        let mut good = Vec::new();
        encode_envelope(&e, &mut good);

        // flipped magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // version bump
        let mut bad = good.clone();
        bad[4] = 0x7F;
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // unknown payload tag
        let mut bad = good.clone();
        bad[18] = 0xEE;
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("tag"), "{err}");

        // oversized length prefix: rejected before any allocation
        let mut bad = good.clone();
        bad[19..23].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
        let mut cursor = std::io::Cursor::new(bad);
        assert!(FrameReader::new().read_from(&mut cursor, None).is_err());

        // length prefix larger than the actual body (trailing-byte check
        // on the other side: shrink len, leaving trailing bytes)
        let mut bad = good.clone();
        let short = (payload_wire_len(&e.payload) - 1) as u32;
        bad[19..23].copy_from_slice(&short.to_le_bytes());
        assert!(decode_envelope(&bad, None).is_err());

        // matrix dims that overflow the frame
        let mut bad = good.clone();
        bad[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("matrix header"), "{err}");

        // scalar out of field range
        let mut bad = good.clone();
        let first_scalar = HEADER_LEN + 8;
        bad[first_scalar..first_scalar + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("field range"), "{err}");

        // unknown control sub-tag
        let ce = env(Payload::Control(ControlMsg::JobAbort));
        let mut bad = Vec::new();
        encode_envelope(&ce, &mut bad);
        bad[HEADER_LEN] = 0x66;
        let err = decode_envelope(&bad, None).unwrap_err();
        assert!(err.to_string().contains("sub-tag"), "{err}");
    }

    #[test]
    fn garbage_streams_never_panic() {
        // A deterministic fuzz sweep: random bytes, random flips of valid
        // frames — every outcome must be Ok or a typed error, never a
        // panic or an absurd allocation.
        let mut rng = ChaChaRng::seed_from_u64(0xF422);
        for round in 0..200u64 {
            let mut buf = Vec::new();
            if round % 2 == 0 {
                let len = (rng.next_u64() % 64) as usize;
                for _ in 0..len {
                    buf.push(rng.next_u64() as u8);
                }
            } else {
                encode_envelope(&env(Payload::GShare(mat(2, 3, round))), &mut buf);
                let flips = 1 + (rng.next_u64() % 4) as usize;
                for _ in 0..flips {
                    let i = (rng.next_u64() as usize) % buf.len();
                    buf[i] ^= (rng.next_u64() as u8) | 1;
                }
            }
            let _ = decode_envelope(&buf, None); // must not panic
            let mut cursor = std::io::Cursor::new(buf);
            let mut fr = FrameReader::new();
            loop {
                match fr.read_from(&mut cursor, None) {
                    Ok(Some(_)) => {}
                    Ok(None) | Err(_) => break,
                }
            }
        }
    }

    // ------------------------------------------------------ client plane

    fn every_client_msg() -> Vec<ClientMsg> {
        vec![
            ClientMsg::Submit {
                s: 2,
                t: 2,
                z: 2,
                adv: 1,
                a: fpmat(4, 4, 21),
                b: fpmat(4, 4, 22),
            },
            ClientMsg::Result {
                digest: 0xD16E57,
                elapsed_us: 1234,
                y: fpmat(3, 3, 23),
            },
            ClientMsg::Reject {
                reason: RejectReason::QuotaExceeded,
                detail: "tenant 7: bucket empty".into(),
            },
            ClientMsg::Reject {
                reason: RejectReason::Internal,
                detail: String::new(),
            },
            ClientMsg::Reject {
                reason: RejectReason::Unauthorized,
                detail: "shutdown token mismatch".into(),
            },
            ClientMsg::Shutdown { token: 0xFEED_FACE },
        ]
    }

    fn assert_client_eq(a: &ClientMsg, b: &ClientMsg) {
        match (a, b) {
            (
                ClientMsg::Submit {
                    s,
                    t,
                    z,
                    adv,
                    a: a1,
                    b: b1,
                },
                ClientMsg::Submit {
                    s: s2,
                    t: t2,
                    z: z2,
                    adv: adv2,
                    a: a2,
                    b: b2,
                },
            ) => {
                assert_eq!((s, t, z, adv), (s2, t2, z2, adv2));
                assert_eq!(a1, a2);
                assert_eq!(b1, b2);
            }
            (
                ClientMsg::Result {
                    digest,
                    elapsed_us,
                    y,
                },
                ClientMsg::Result {
                    digest: d2,
                    elapsed_us: e2,
                    y: y2,
                },
            ) => {
                assert_eq!(digest, d2);
                assert_eq!(elapsed_us, e2);
                assert_eq!(y, y2);
            }
            (
                ClientMsg::Reject { reason, detail },
                ClientMsg::Reject {
                    reason: r2,
                    detail: d2,
                },
            ) => {
                assert_eq!(reason, r2);
                assert_eq!(detail, d2);
            }
            (ClientMsg::Shutdown { token }, ClientMsg::Shutdown { token: t2 }) => {
                assert_eq!(token, t2);
            }
            (x, y) => panic!("client variant mismatch: {x:?} vs {y:?}"),
        }
    }

    #[test]
    fn client_frames_roundtrip_incrementally_and_over_streams() {
        for (i, msg) in every_client_msg().into_iter().enumerate() {
            let f = ClientFrame {
                corr: 0xC0FFEE + i as u64,
                tenant: 3 + i as u32,
                msg,
            };
            let mut buf = Vec::new();
            encode_client_frame(&f, &mut buf);
            assert_eq!(buf.len(), client_frame_len(&f), "len disagrees for {f:?}");
            let h = peek_client_header(&buf).unwrap().unwrap();
            assert_eq!(h.corr, f.corr);
            assert_eq!(h.tenant, f.tenant);
            assert_eq!(h.payload_len, buf.len() - HEADER_LEN);
            let (back, consumed) = decode_client_frame(&buf).unwrap().unwrap();
            assert_eq!(consumed, buf.len());
            assert_eq!(back.corr, f.corr);
            assert_eq!(back.tenant, f.tenant);
            assert_client_eq(&back.msg, &f.msg);
            let mut cursor = std::io::Cursor::new(buf);
            let back = read_client_frame(&mut cursor).unwrap().unwrap();
            assert_client_eq(&back.msg, &f.msg);
            assert!(read_client_frame(&mut cursor).unwrap().is_none());
        }
    }

    #[test]
    fn partial_client_frames_are_incomplete_not_errors() {
        // The incremental decoder must treat every prefix of a valid frame
        // as "keep reading" — that is what lets the poller parse from
        // partial nonblocking reads. The blocking stream reader, by
        // contrast, sees the same prefix as a peer dying mid-frame.
        for msg in every_client_msg() {
            let f = ClientFrame {
                corr: 1,
                tenant: 2,
                msg,
            };
            let mut buf = Vec::new();
            encode_client_frame(&f, &mut buf);
            for cut in 0..buf.len() {
                match decode_client_frame(&buf[..cut]) {
                    Ok(None) => {}
                    other => panic!("cut at {cut}: {other:?}"),
                }
                let mut cursor = std::io::Cursor::new(buf[..cut].to_vec());
                let got = read_client_frame(&mut cursor);
                if cut == 0 {
                    assert!(matches!(got, Ok(None)));
                } else {
                    assert!(got.is_err(), "stream cut at {cut} did not error");
                }
            }
        }
    }

    #[test]
    fn client_plane_and_fabric_plane_reject_each_other() {
        // A fabric frame fed to the client decoder is an unknown tag...
        let mut buf = Vec::new();
        encode_envelope(&env(Payload::GShare(mat(2, 2, 31))), &mut buf);
        let err = decode_client_frame(&buf).unwrap_err();
        assert!(err.to_string().contains("client frame tag"), "{err}");
        // ...and a client frame fed to the fabric decoder likewise, so a
        // client socket can never inject Phase-2 traffic.
        let f = ClientFrame {
            corr: 9,
            tenant: 1,
            msg: ClientMsg::Shutdown { token: 0 },
        };
        let mut buf = Vec::new();
        encode_client_frame(&f, &mut buf);
        let err = decode_envelope(&buf, None).unwrap_err();
        assert!(err.to_string().contains("tag"), "{err}");
    }

    #[test]
    fn corrupt_client_frames_are_typed_errors() {
        let f = ClientFrame {
            corr: 5,
            tenant: 0,
            msg: ClientMsg::Submit {
                s: 2,
                t: 2,
                z: 2,
                adv: 0,
                a: fpmat(2, 2, 41),
                b: fpmat(2, 2, 42),
            },
        };
        let mut good = Vec::new();
        encode_client_frame(&f, &mut good);

        // flipped magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(peek_client_header(&bad).is_err());

        // oversized length prefix: rejected from the header alone
        let mut bad = good.clone();
        bad[19..23].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = peek_client_header(&bad).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");

        // matrix dims that overflow the frame (A's dims sit after s,t,z,adv)
        let mut bad = good.clone();
        bad[HEADER_LEN + 16..HEADER_LEN + 20].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_client_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("matrix header"), "{err}");

        // scalar out of field range
        let mut bad = good.clone();
        let first_scalar = HEADER_LEN + 16 + 8;
        bad[first_scalar..first_scalar + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_client_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("field range"), "{err}");

        // unknown reject-reason code
        let rj = ClientFrame {
            corr: 5,
            tenant: 0,
            msg: ClientMsg::Reject {
                reason: RejectReason::Malformed,
                detail: "x".into(),
            },
        };
        let mut bad = Vec::new();
        encode_client_frame(&rj, &mut bad);
        bad[HEADER_LEN] = 0x77;
        let err = decode_client_frame(&bad).unwrap_err();
        assert!(err.to_string().contains("reject reason"), "{err}");
    }

    #[test]
    fn garbage_client_streams_never_panic() {
        let mut rng = ChaChaRng::seed_from_u64(0xC11E);
        for round in 0..200u64 {
            let mut buf = Vec::new();
            if round % 2 == 0 {
                let len = (rng.next_u64() % 64) as usize;
                for _ in 0..len {
                    buf.push(rng.next_u64() as u8);
                }
            } else {
                let f = ClientFrame {
                    corr: round,
                    tenant: 1,
                    msg: ClientMsg::Submit {
                        s: 2,
                        t: 2,
                        z: 2,
                        adv: (round % 3) as usize,
                        a: fpmat(2, 3, round),
                        b: fpmat(3, 2, round + 1),
                    },
                };
                encode_client_frame(&f, &mut buf);
                let flips = 1 + (rng.next_u64() % 4) as usize;
                for _ in 0..flips {
                    let i = (rng.next_u64() as usize) % buf.len();
                    buf[i] ^= (rng.next_u64() as u8) | 1;
                }
            }
            let _ = decode_client_frame(&buf); // must not panic
            let mut cursor = std::io::Cursor::new(buf);
            let _ = read_client_frame(&mut cursor);
        }
    }
}
