//! Multi-node runner: one OS process (or thread) per CMPC party, over TCP.
//!
//! `cmpc node --role worker|master|source-a|source-b --manifest <path>`
//! runs exactly one party; a [`TopologyManifest`] read by every process
//! makes the cluster self-consistent (same scheme resolution, same α
//! assignment, same per-job seeds and demo data). The protocol state
//! machines are the *same code* the in-process runtime drives —
//! [`serve_worker`] for workers, [`run_master`] + [`JobRouter`] for the
//! master — only the transport underneath changes, so a multi-process run
//! decodes `Y` byte-identical to the in-process fabric (pinned by
//! `tests/distributed.rs` and the CI multi-process lane).
//!
//! Division of labor per the paper's topology:
//!
//! * **master** — drives the jobs: announces each [`ControlMsg::JobStart`]
//!   (to workers *and* sources), runs Phase-3 reconstruction, verifies
//!   `Y = AᵀB` locally, reports digests/traffic, and shuts the cluster
//!   down after the last job (even on failure, so peers never hang).
//! * **source-a / source-b** — hold `A` resp. `B` (derived from the
//!   manifest seed per job, so the demo needs no data distribution),
//!   build their share polynomial on each `JobStart`, and send
//!   [`Payload::ShareA`] / [`Payload::ShareB`] evaluations to every
//!   worker — the split form of Phase 1, since neither source holds the
//!   other's matrix.
//! * **worker `i`** — `serve_worker` verbatim: Phase-2 compute, the
//!   G-exchange with every peer, `I(αᵢ)` to the master.
//!
//! [`run_local_cluster`] runs the same topology inside one process —
//! every node a thread, every link a real 127.0.0.1 socket — which is how
//! the tests and the bench measure on-wire bytes against the analytical ζ.
//!
//! **Pipelines (v0.10).** When the manifest carries a `pipeline` line,
//! each of its `jobs` is one full [`crate::mpc::pipeline::Pipeline`] run.
//! The master announces every round with [`ControlMsg::StageStart`]; the
//! sources react per round — round 0 exactly like a normal job (split
//! `ShareA`/`ShareB`), later rounds as the **split re-share**: the master
//! sends each worker its evaluation of `build_f_a(Z′)`
//! ([`ControlMsg::StageShareZ`]), source A the matching mask-residual
//! evaluation ([`ControlMsg::StageShareR`]), and the worker's difference
//! is, by GF(p) linearity, a fresh A-share of the true next state — which
//! no single party ever materializes. Source B sends the round's weight
//! shares plus, for intermediate rounds, the stage mask
//! ([`Payload::StageMask`]). All three drivers (this module, the
//! in-process runtime, [`crate::mpc::pipeline::reference_eval`]) derive
//! identical randomness from the stage seeds, so the decoded output is
//! byte-identical across them — pinned by `tests/pipeline.rs` and the CI
//! pipeline lane.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::SchemeParams;
use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::metrics::{RuntimeCounters, TrafficReport, WireStats, WorkerCounters};
use crate::mpc::chaos::ChaosPlan;
use crate::mpc::deployment::Deployment;
use crate::mpc::master::run_master;
use crate::mpc::network::{
    ControlMsg, Endpoint, Fabric, FabricTuning, JobId, JobRouter, NodeId, Payload, PooledMat,
    Transport, CONTROL_JOB,
};
use crate::mpc::pipeline::{self, Pipeline};
use crate::mpc::protocol::{prepare_setup, ProtocolConfig};
use crate::mpc::source;
use crate::mpc::worker::{serve_worker, WorkerCtx};
use crate::runtime::manifest::TopologyManifest;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::{BackendChoice, BackendFactory};
use crate::transport::tcp::TcpTransport;
use crate::util::rng::ChaChaRng;

/// Which party this process plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRole {
    /// Phase-2 worker with the given index.
    Worker(usize),
    /// The decoding master that drives the run.
    Master,
    /// Source holding `A`.
    SourceA,
    /// Source holding `B`.
    SourceB,
}

impl NodeRole {
    /// Parse a `--role` string (+ `--index` for workers).
    pub fn parse(role: &str, index: Option<usize>) -> Result<NodeRole> {
        match role {
            "worker" => match index {
                Some(i) => Ok(NodeRole::Worker(i)),
                None => Err(CmpcError::InvalidParams(
                    "role worker needs --index <worker id>".to_string(),
                )),
            },
            "master" => Ok(NodeRole::Master),
            "source-a" => Ok(NodeRole::SourceA),
            "source-b" => Ok(NodeRole::SourceB),
            other => Err(CmpcError::InvalidParams(format!(
                "unknown role {other:?} (worker|master|source-a|source-b)"
            ))),
        }
    }
}

/// Per-job secret seed — delegates to the same derivation
/// [`Deployment::execute`] uses ([`crate::mpc::deployment::derive_job_seed`]),
/// which is what makes a distributed run byte-identical to the in-process
/// reference.
pub fn job_secret_seed(base: u64, job: JobId) -> u64 {
    crate::mpc::deployment::derive_job_seed(base, job)
}

/// The demo input matrices of one job, derived from the manifest seed so
/// every party (and the in-process reference) agrees without any data
/// distribution. Source A uses `A`, source B uses `B`, the master uses
/// both for verification.
pub fn job_matrices(base: u64, job: JobId, m: usize) -> (FpMat, FpMat) {
    let seed = base ^ job.wrapping_mul(0xD6E8_FEB8_6659_FD93).wrapping_add(0x5851_F42D);
    let mut rng = ChaChaRng::seed_from_u64(seed);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    (a, b)
}

fn fnv1a(h: &mut u64, byte: u8) {
    *h ^= byte as u64;
    *h = h.wrapping_mul(0x0000_0100_0000_01B3);
}

/// FNV-1a digest over a matrix's dimensions and scalars — the compact
/// output-equality witness the CI lane diffs between the distributed
/// master and the in-process reference.
pub fn digest_mat(m: &FpMat) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for d in [m.rows as u64, m.cols as u64] {
        for byte in d.to_le_bytes() {
            fnv1a(&mut h, byte);
        }
    }
    for &v in &m.data {
        for byte in v.to_le_bytes() {
            fnv1a(&mut h, byte);
        }
    }
    h
}

/// How long a long-running role (worker, source) tolerates a completely
/// silent fabric before concluding the master is gone.
///
/// The window must comfortably exceed the longest **inter-job gap** — the
/// master verifies `Y = AᵀB` single-threaded between jobs, so at large `m`
/// with a small `recv_timeout_ms` the 4× multiple can get tight; raise
/// `recv_timeout_ms` in the manifest if idle peers bail mid-run.
fn idle_budget(manifest: &TopologyManifest) -> Duration {
    manifest
        .recv_timeout
        .saturating_mul(4)
        .max(Duration::from_secs(1))
}

fn over_tcp(
    manifest: &TopologyManifest,
    transport: &Arc<TcpTransport>,
    chaos: Option<Arc<ChaosPlan>>,
) -> Arc<Fabric> {
    let t: Arc<dyn Transport> = transport.clone();
    Fabric::over_transport(
        t,
        FabricTuning {
            link_delay: None,
            chaos,
            shaper: manifest.shaper(),
        },
    )
}

/// Serve worker `index` over `transport` until the master's shutdown (or a
/// chaos kill / self-eviction). The state machine is the in-process
/// [`serve_worker`], unchanged.
pub fn serve_worker_node(
    manifest: &TopologyManifest,
    index: usize,
    transport: Arc<TcpTransport>,
    endpoint: Endpoint,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<()> {
    if index >= manifest.n_workers() {
        return Err(CmpcError::InvalidParams(format!(
            "worker index {index} outside the manifest's {} workers",
            manifest.n_workers()
        )));
    }
    let scheme = manifest.resolve_scheme()?;
    let p = scheme.params();
    let setup = prepare_setup(scheme.as_ref())?;
    let fabric = over_tcp(manifest, &transport, chaos);
    let ctx = WorkerCtx {
        id: index,
        n_workers: setup.n_workers,
        t: p.t,
        z: p.z,
        alphas: setup.alphas.clone(),
        r_coeffs: setup.r_coeffs.clone(),
        delay: Duration::ZERO,
        recv_timeout: manifest.recv_timeout,
        max_deadline_misses: ProtocolConfig::default().max_deadline_misses,
        // An orphaned worker process (master killed before its shutdown
        // broadcast) terminates after a silent idle window instead of
        // leaking — same bound the sources use.
        idle_timeout: Some(idle_budget(manifest)),
        health: Arc::new(RuntimeCounters::default()),
    };
    let factory = BackendFactory::new(&BackendChoice::Native)?;
    serve_worker(
        ctx,
        endpoint,
        fabric,
        factory.make(),
        transport.buffers().clone(),
    )
}

/// Serve one source role: on every [`ControlMsg::JobStart`], build the
/// share polynomial for this source's matrix and send the split Phase-1
/// shares to every worker. [`ControlMsg::JobInput`] (the gateway's remote
/// engine) is the same pipeline with a *pushed* client matrix in place of
/// the manifest-derived demo data. Exits on shutdown — or after a long
/// idle window (4× the receive timeout) with no master traffic at all, so
/// a crashed master cannot strand source processes forever.
pub fn serve_source_node(
    manifest: &TopologyManifest,
    is_source_a: bool,
    transport: Arc<TcpTransport>,
    endpoint: Endpoint,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<()> {
    let scheme = manifest.resolve_scheme()?;
    let p = scheme.params();
    let setup = prepare_setup(scheme.as_ref())?;
    let pipe = manifest.pipeline()?;
    let fabric = over_tcp(manifest, &transport, chaos);
    let my_id = if is_source_a {
        manifest.source_a_id()
    } else {
        manifest.source_b_id()
    };
    let idle = idle_budget(manifest);
    let emit = |job: JobId, seed: u64, mine: &FpMat| {
        // Fork order must match the in-process driver: source A takes the
        // job rng's first fork, source B the second (each process draws
        // both, uses its own).
        let mut job_rng = ChaChaRng::seed_from_u64(seed);
        let mut rng_a = job_rng.fork();
        let mut rng_b = job_rng.fork();
        let poly = if is_source_a {
            source::build_f_a(scheme.as_ref(), mine, &mut rng_a)
        } else {
            source::build_f_b(scheme.as_ref(), mine, &mut rng_b)
        };
        for (wid, share) in source::shares(&poly, &setup.alphas).into_iter().enumerate() {
            let payload = if is_source_a {
                Payload::ShareA(PooledMat::detached(share))
            } else {
                Payload::ShareB(PooledMat::detached(share))
            };
            // A dead worker is the master's problem (its job will
            // fail or early-decode around it); the source keeps
            // serving later jobs either way.
            let _ = fabric.send(job, my_id, wid, payload);
        }
    };
    loop {
        let env = match endpoint.recv_timeout(idle) {
            Ok(env) => env,
            // No master traffic for the whole idle window: the driver is
            // gone (crashed before its shutdown broadcast) — bail out.
            Err(_) => return Ok(()),
        };
        match env.payload {
            Payload::Control(ControlMsg::Shutdown) => return Ok(()),
            Payload::Control(ControlMsg::JobStart { seed, .. }) => {
                let (a, b) = job_matrices(manifest.seed, env.job, manifest.m);
                emit(env.job, seed, if is_source_a { &a } else { &b });
            }
            // Gateway push (v0.7): the client's matrix replaces the
            // manifest-derived demo data; masks and fork order are
            // unchanged, so decode needs no new code anywhere.
            Payload::Control(ControlMsg::JobInput { seed, mat }) => {
                emit(env.job, seed, &mat);
            }
            // Pipeline round cue (v0.10): the manifest's `pipeline` line
            // tells this source what each round needs from it.
            Payload::Control(ControlMsg::StageStart {
                stage,
                seed,
                masked,
                ..
            }) => {
                // A stage cue without a pipeline line is stray traffic
                // from a mismatched master; sources hold no state to harm.
                let Some(pipe) = pipe.as_ref() else { continue };
                let r = stage as usize;
                // Fabric job ids pack as run*rounds + r, so the run index
                // (hence the run's data) is derivable in every process.
                let run = env.job / pipe.rounds() as u64;
                let pipeline_seed = job_secret_seed(manifest.seed, run);
                if is_source_a {
                    if r == 0 {
                        // First round: ordinary Phase 1 over the run input
                        // (same fork order as every other driver).
                        let x = pipeline::pipeline_input(pipeline_seed, manifest.m);
                        let mut job_rng = ChaChaRng::seed_from_u64(seed);
                        let mut rng_a = job_rng.fork();
                        let poly = source::build_f_a(scheme.as_ref(), &x, &mut rng_a);
                        for (wid, share) in
                            source::shares(&poly, &setup.alphas).into_iter().enumerate()
                        {
                            let _ = fabric.send(
                                env.job,
                                my_id,
                                wid,
                                Payload::ShareA(PooledMat::detached(share)),
                            );
                        }
                    } else {
                        // Later rounds: replay the previous round's mask
                        // (seed-derived, never received) through its
                        // boundary ops and share the secret-term-free
                        // residual — the worker subtracts it from the
                        // master's Z′ share to get a fresh share of the
                        // true next state.
                        let seed_prev = pipeline::stage_seed(pipeline_seed, stage - 1);
                        let blocks = pipeline::stage_mask_blocks(
                            p.t,
                            manifest.m / p.t,
                            pipe.bounded_mask(r - 1),
                            seed_prev,
                        );
                        let r_mat = FpMat::from_blocks(&blocks);
                        let r_prime = pipeline::apply_ops(r_mat, pipe.boundary(r - 1), false);
                        let poly = pipeline::residual_poly_a(scheme.as_ref(), &r_prime);
                        for (wid, &alpha) in setup.alphas.iter().enumerate() {
                            let _ = fabric.send(
                                env.job,
                                my_id,
                                wid,
                                Payload::Control(ControlMsg::StageShareR {
                                    stage,
                                    mat: poly.eval(alpha),
                                }),
                            );
                        }
                    }
                } else {
                    // Source B: the stage mask first (cheap), so a fast
                    // worker never stalls on it, then the round's weight
                    // shares under the second rng fork.
                    if masked {
                        let blocks = pipeline::stage_mask_blocks(
                            p.t,
                            manifest.m / p.t,
                            pipe.bounded_mask(r),
                            seed,
                        );
                        let d_poly = pipeline::stage_mask_poly(&blocks, p.t);
                        for (wid, &alpha) in setup.alphas.iter().enumerate() {
                            let _ = fabric.send(
                                env.job,
                                my_id,
                                wid,
                                Payload::StageMask {
                                    stage,
                                    mat: PooledMat::detached(d_poly.eval(alpha)),
                                },
                            );
                        }
                    }
                    let w = pipeline::pipeline_weight(pipeline_seed, manifest.m, stage);
                    let mut job_rng = ChaChaRng::seed_from_u64(seed);
                    let _ = job_rng.fork();
                    let mut rng_b = job_rng.fork();
                    let poly = source::build_f_b(scheme.as_ref(), &w, &mut rng_b);
                    for (wid, share) in
                        source::shares(&poly, &setup.alphas).into_iter().enumerate()
                    {
                        let _ = fabric.send(
                            env.job,
                            my_id,
                            wid,
                            Payload::ShareB(PooledMat::detached(share)),
                        );
                    }
                }
            }
            // Stray traffic (e.g. a JobAbort for a failed job): sources
            // hold no per-job state, nothing to drop.
            _ => {}
        }
    }
}

/// One finished job as observed by the distributed master.
pub struct NodeJobReport {
    /// Job id within the run.
    pub job: JobId,
    /// The reconstructed output.
    pub y: FpMat,
    /// FNV digest of `y` ([`digest_mat`]).
    pub digest: u64,
    /// Whether the local check against the expected output passed
    /// (always false when the manifest disables verification).
    pub verified: bool,
    /// Whether the master decoded at the quota and aborted stragglers.
    pub early_decoded: bool,
    /// Worker ids whose I-shares arrived garbled and were located and
    /// excluded by the Byzantine decoder (sorted; empty unless the
    /// manifest sets `adversary_tolerance > 0` and corruption occurred).
    pub blamed_workers: Vec<usize>,
    /// Scalar traffic metered by the **master process's own fabric** —
    /// near-zero in a distributed run, since each process meters only its
    /// own sends (the ζ legs live in the worker processes; the measured
    /// distributed form is the wire stats).
    pub traffic: TrafficReport,
    /// Per-worker ξ/σ counters, finalized from the totals each worker
    /// reported in its `JobDone`/`AbortAck` — exact across process
    /// boundaries.
    pub worker_counters: Vec<Arc<WorkerCounters>>,
    /// Wall-clock time from `JobStart` to the verified decode.
    pub elapsed: Duration,
}

/// Everything the master learned in one distributed run.
pub struct MasterRunReport {
    /// Per-job reports, in drive order.
    pub jobs: Vec<NodeJobReport>,
    /// Bytes this master process itself put on the wire (the cluster
    /// harness additionally sums every node's transport).
    pub wire: WireStats,
}

/// Drive `manifest.jobs` jobs as the master node, then shut the cluster
/// down — **also on failure**, so worker and source processes never hang
/// on a dead driver.
///
/// A worker that is unreachable **at `JobStart`** fails the run fast (the
/// send `?`s out after the connect budget). That is deliberate, not a gap
/// in the straggler story: the code tolerates workers that straggle or die
/// *after* delivering their G-exchange contribution, but every `I(αₙ)`
/// sums all `N` G-shares, so a worker dead before Phase 2 makes the job
/// undecodable no matter how long the master waits — failing at the first
/// send is the cheapest honest outcome. (In-process deployments recover
/// across jobs via the runtime's respawn reaper; the distributed analogue
/// is the reconnect-and-rejoin item in ROADMAP.)
pub fn run_master_node(
    manifest: &TopologyManifest,
    transport: Arc<TcpTransport>,
    endpoint: Endpoint,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<MasterRunReport> {
    if let Some(pipe) = manifest.pipeline()? {
        return run_pipeline_master_node(manifest, &pipe, transport, endpoint, chaos);
    }
    let scheme = manifest.resolve_scheme()?;
    let p = scheme.params();
    let setup = prepare_setup(scheme.as_ref())?;
    let n = setup.n_workers;
    let fabric = over_tcp(manifest, &transport, chaos);
    let router = JobRouter::new(endpoint);
    let pool = WorkerPool::sized_or_global(0);
    let scratch = ScratchPool::for_pool(&pool);
    let master_id = manifest.master_id();

    let drive = || -> Result<Vec<NodeJobReport>> {
        let mut reports = Vec::new();
        for k in 0..manifest.jobs {
            let job = k as JobId;
            router.open(job);
            fabric.begin_job(job);
            let t0 = Instant::now();
            let outcome = (|| -> Result<(FpMat, Vec<Arc<WorkerCounters>>, bool, Vec<usize>)> {
                let seed = job_secret_seed(manifest.seed, job);
                let counters: Vec<Arc<WorkerCounters>> =
                    (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
                for (wid, c) in counters.iter().enumerate() {
                    fabric.send(
                        job,
                        master_id,
                        wid,
                        Payload::Control(ControlMsg::JobStart {
                            seed,
                            counters: c.clone(),
                        }),
                    )?;
                }
                // The sources' cue to encode and send this job's shares.
                for src in [manifest.source_a_id(), manifest.source_b_id()] {
                    fabric.send(
                        job,
                        master_id,
                        src,
                        Payload::Control(ControlMsg::JobStart {
                            seed,
                            counters: Arc::new(WorkerCounters::default()),
                        }),
                    )?;
                }
                let (m_out, _mt) = run_master(
                    &router,
                    &fabric,
                    job,
                    &setup.alphas,
                    n,
                    p.t,
                    p.z,
                    p.adversary_tolerance,
                    manifest.recv_timeout,
                    manifest.early_decode,
                    &counters,
                    &pool,
                    &scratch,
                )?;
                Ok((m_out.y, counters, m_out.early_decoded, m_out.blamed_workers))
            })();
            let traffic = fabric.end_job(job);
            router.close(job);
            match outcome {
                Ok((y, worker_counters, early_decoded, blamed_workers)) => {
                    let verified = if manifest.verify {
                        let (a, b) = job_matrices(manifest.seed, job, manifest.m);
                        let ok = y == a.transpose().matmul(&b);
                        if !ok {
                            return Err(CmpcError::NotDecodable(format!(
                                "job {job}: distributed reconstruction mismatch: Y != AᵀB"
                            )));
                        }
                        ok
                    } else {
                        false
                    };
                    reports.push(NodeJobReport {
                        job,
                        digest: digest_mat(&y),
                        y,
                        verified,
                        early_decoded,
                        blamed_workers,
                        traffic,
                        worker_counters,
                        elapsed: t0.elapsed(),
                    });
                }
                Err(e) => {
                    // Free the workers' state for the failed job before
                    // giving up.
                    for wid in 0..n {
                        let _ = fabric.send(
                            job,
                            master_id,
                            wid,
                            Payload::Control(ControlMsg::JobAbort),
                        );
                    }
                    return Err(e);
                }
            }
        }
        Ok(reports)
    };
    let result = drive();
    // Tear the cluster down no matter what happened above. One retry per
    // node: a write onto a connection that died since the last job marks
    // it broken and reconnects on the second attempt — a live worker
    // stranded without its shutdown would otherwise idle for the whole
    // orphan window.
    let mut peers: Vec<NodeId> = (0..n).collect();
    peers.push(manifest.source_a_id());
    peers.push(manifest.source_b_id());
    for peer in peers {
        for _attempt in 0..2 {
            if fabric
                .send(
                    CONTROL_JOB,
                    master_id,
                    peer,
                    Payload::Control(ControlMsg::Shutdown),
                )
                .is_ok()
            {
                break;
            }
        }
    }
    let jobs = result?;
    Ok(MasterRunReport {
        jobs,
        wire: transport.wire_stats(),
    })
}

/// Drive `manifest.jobs` pipeline runs as the master node (the manifest
/// carries a `pipeline` line), then shut the cluster down. Each run is
/// [`Pipeline::rounds`] fabric jobs (packed ids `run*rounds + r`); every
/// intermediate round ends in a masked-open collect at the stage quota,
/// only the final round in a Phase-3 decode — so the cluster decodes
/// exactly one `Y` per run, like the in-process driver.
fn run_pipeline_master_node(
    manifest: &TopologyManifest,
    pipe: &Pipeline,
    transport: Arc<TcpTransport>,
    endpoint: Endpoint,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<MasterRunReport> {
    let scheme = manifest.resolve_scheme()?;
    let p = scheme.params();
    let setup = prepare_setup(scheme.as_ref())?;
    let n = setup.n_workers;
    let fabric = over_tcp(manifest, &transport, chaos);
    let router = JobRouter::new(endpoint);
    let pool = WorkerPool::sized_or_global(0);
    let scratch = ScratchPool::for_pool(&pool);
    let master_id = manifest.master_id();
    let rounds = pipe.rounds();

    let drive = || -> Result<Vec<NodeJobReport>> {
        let mut reports = Vec::new();
        for k in 0..manifest.jobs {
            let t0 = Instant::now();
            let pipeline_seed = job_secret_seed(manifest.seed, k as JobId);
            let x0 = pipeline::pipeline_input(pipeline_seed, manifest.m);
            let weights: Vec<FpMat> = (0..rounds)
                .map(|r| pipeline::pipeline_weight(pipeline_seed, manifest.m, r as u32))
                .collect();
            // The boundary-advanced masked open Z′ awaiting re-share (the
            // master's half; source A's residual carries the other half).
            let mut state_z: Option<FpMat> = None;
            let mut y = FpMat::zeros(0, 0);
            let mut early_decoded = false;
            let mut final_counters: Vec<Arc<WorkerCounters>> = Vec::new();
            let mut traffic = TrafficReport::default();
            for r in 0..rounds {
                let job = (k * rounds + r) as JobId;
                let seed_r = pipeline::stage_seed(pipeline_seed, r as u32);
                let masked = r + 1 < rounds;
                router.open(job);
                fabric.begin_job(job);
                let outcome = (|| -> Result<(FpMat, Vec<Arc<WorkerCounters>>, bool)> {
                    let counters: Vec<Arc<WorkerCounters>> =
                        (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
                    for (wid, c) in counters.iter().enumerate() {
                        fabric.send(
                            job,
                            master_id,
                            wid,
                            Payload::Control(ControlMsg::StageStart {
                                stage: r as u32,
                                seed: seed_r,
                                masked,
                                counters: c.clone(),
                            }),
                        )?;
                    }
                    // The sources' cue for this round.
                    for src in [manifest.source_a_id(), manifest.source_b_id()] {
                        fabric.send(
                            job,
                            master_id,
                            src,
                            Payload::Control(ControlMsg::StageStart {
                                stage: r as u32,
                                seed: seed_r,
                                masked,
                                counters: Arc::new(WorkerCounters::default()),
                            }),
                        )?;
                    }
                    if let Some(z_prime) = state_z.as_ref() {
                        // Split re-share, master's half: the same rng fork
                        // the in-process source-A role would take, so the
                        // secret terms (and hence every worker's combined
                        // share) are byte-identical to the fused
                        // build_f_a(Z′ − R′) the in-process driver sends.
                        let mut job_rng = ChaChaRng::seed_from_u64(seed_r);
                        let mut rng_a = job_rng.fork();
                        let fa_z = source::build_f_a(scheme.as_ref(), z_prime, &mut rng_a);
                        for (wid, &alpha) in setup.alphas.iter().enumerate() {
                            fabric.send(
                                job,
                                master_id,
                                wid,
                                Payload::Control(ControlMsg::StageShareZ {
                                    stage: r as u32,
                                    mat: fa_z.eval(alpha),
                                }),
                            )?;
                        }
                    }
                    if masked {
                        let z = pipeline::collect_stage(
                            &router,
                            &fabric,
                            job,
                            r as u32,
                            &setup.alphas,
                            n,
                            p.t,
                            p.stage_quota(),
                            manifest.recv_timeout,
                            &counters,
                        )?;
                        Ok((z, counters, false))
                    } else {
                        let (m_out, _mt) = run_master(
                            &router,
                            &fabric,
                            job,
                            &setup.alphas,
                            n,
                            p.t,
                            p.z,
                            0,
                            manifest.recv_timeout,
                            manifest.early_decode,
                            &counters,
                            &pool,
                            &scratch,
                        )?;
                        Ok((m_out.y, counters, m_out.early_decoded))
                    }
                })();
                let stage_traffic = fabric.end_job(job);
                router.close(job);
                match outcome {
                    Ok((mat, counters, early)) => {
                        traffic.source_to_worker += stage_traffic.source_to_worker;
                        traffic.worker_to_worker += stage_traffic.worker_to_worker;
                        traffic.worker_to_master += stage_traffic.worker_to_master;
                        traffic.messages += stage_traffic.messages;
                        if masked {
                            state_z = Some(pipeline::apply_ops(mat, pipe.boundary(r), true));
                        } else {
                            early_decoded = early;
                            y = pipeline::apply_ops(mat, pipe.boundary(r), true);
                            final_counters = counters;
                        }
                    }
                    Err(e) => {
                        for wid in 0..n {
                            let _ = fabric.send(
                                job,
                                master_id,
                                wid,
                                Payload::Control(ControlMsg::JobAbort),
                            );
                        }
                        return Err(e);
                    }
                }
            }
            let verified = if manifest.verify {
                let wrefs: Vec<&FpMat> = weights.iter().collect();
                let expect = pipeline::reference_eval(pipe, p, &x0, &wrefs, pipeline_seed)?;
                if y != expect {
                    return Err(CmpcError::NotDecodable(format!(
                        "pipeline run {k}: distributed reconstruction mismatch vs the \
                         decode-re-encode reference"
                    )));
                }
                true
            } else {
                false
            };
            reports.push(NodeJobReport {
                job: k as JobId,
                digest: digest_mat(&y),
                y,
                verified,
                early_decoded,
                blamed_workers: Vec::new(),
                traffic,
                worker_counters: final_counters,
                elapsed: t0.elapsed(),
            });
        }
        Ok(reports)
    };
    let result = drive();
    let mut peers: Vec<NodeId> = (0..n).collect();
    peers.push(manifest.source_a_id());
    peers.push(manifest.source_b_id());
    for peer in peers {
        for _attempt in 0..2 {
            if fabric
                .send(
                    CONTROL_JOB,
                    master_id,
                    peer,
                    Payload::Control(ControlMsg::Shutdown),
                )
                .is_ok()
            {
                break;
            }
        }
    }
    let jobs = result?;
    Ok(MasterRunReport {
        jobs,
        wire: transport.wire_stats(),
    })
}

/// Bind this role's listener per the manifest and run it. Returns the
/// master's report when the role is [`NodeRole::Master`], `None` for the
/// long-running roles.
pub fn run_role(
    role: NodeRole,
    manifest: &TopologyManifest,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<Option<MasterRunReport>> {
    manifest.validate()?;
    match role {
        NodeRole::Worker(i) => {
            let (t, e) = TcpTransport::bind_manifest(manifest, i)?;
            serve_worker_node(manifest, i, t, e, chaos)?;
            Ok(None)
        }
        NodeRole::Master => {
            let (t, e) = TcpTransport::bind_manifest(manifest, manifest.master_id())?;
            Ok(Some(run_master_node(manifest, t, e, chaos)?))
        }
        NodeRole::SourceA => {
            let (t, e) = TcpTransport::bind_manifest(manifest, manifest.source_a_id())?;
            serve_source_node(manifest, true, t, e, chaos)?;
            Ok(None)
        }
        NodeRole::SourceB => {
            let (t, e) = TcpTransport::bind_manifest(manifest, manifest.source_b_id())?;
            serve_source_node(manifest, false, t, e, chaos)?;
            Ok(None)
        }
    }
}

/// Run the manifest's jobs through the **in-process** session API
/// (provision once, `execute_seeded` with the same per-job seeds and
/// data) and return `(job, digest)` pairs — the reference the CI lane
/// diffs the distributed master's output against.
pub fn run_reference(manifest: &TopologyManifest) -> Result<Vec<(JobId, u64)>> {
    manifest.validate()?;
    let dep = Deployment::provision(
        manifest.spec()?,
        SchemeParams::try_new(manifest.s, manifest.t, manifest.z)?
            .with_adversary_tolerance(manifest.adversary_tolerance),
        ProtocolConfig::builder().verify(manifest.verify).build(),
    )?;
    let mut digests = Vec::with_capacity(manifest.jobs);
    if let Some(pipe) = manifest.pipeline()? {
        // Pipeline topology: each "job" is a full in-process pipeline run
        // under the same per-run seed/data derivations as the cluster.
        for k in 0..manifest.jobs {
            let job = k as JobId;
            let pipeline_seed = job_secret_seed(manifest.seed, job);
            let x = pipeline::pipeline_input(pipeline_seed, manifest.m);
            let weights: Vec<FpMat> = (0..pipe.rounds())
                .map(|r| pipeline::pipeline_weight(pipeline_seed, manifest.m, r as u32))
                .collect();
            let wrefs: Vec<&FpMat> = weights.iter().collect();
            let out = dep.execute_pipeline_seeded(&pipe, &x, &wrefs, pipeline_seed)?;
            digests.push((job, digest_mat(&out.y)));
        }
        return Ok(digests);
    }
    for k in 0..manifest.jobs {
        let job = k as JobId;
        let (a, b) = job_matrices(manifest.seed, job, manifest.m);
        let out = dep.execute_seeded(&a, &b, job_secret_seed(manifest.seed, job))?;
        digests.push((job, digest_mat(&out.y)));
    }
    Ok(digests)
}

/// A whole-cluster loopback run: every node a thread in this process,
/// every link a real 127.0.0.1 socket.
pub struct ClusterReport {
    /// What the master-node thread reported.
    pub master: MasterRunReport,
    /// Wire stats summed over **every** node's transport — this is where
    /// the measured worker↔worker bytes compare against ζ.
    pub wire: WireStats,
}

/// Run the manifest's whole topology over loopback TCP inside this
/// process. Manifest addresses may use port `0`: all listeners bind
/// first, then the real ports are distributed to every node.
///
/// A chaos plan, when given, is attached to every node's fabric (sharing
/// one `Arc`, so rule hit-counters behave exactly as on the in-process
/// single fabric).
pub fn run_local_cluster(
    manifest: &TopologyManifest,
    chaos: Option<Arc<ChaosPlan>>,
) -> Result<ClusterReport> {
    manifest.validate()?;
    let mut listeners = Vec::with_capacity(manifest.n_nodes());
    for addr in manifest.addrs() {
        listeners.push(
            TcpListener::bind(&addr)
                .map_err(|e| CmpcError::Io(format!("cluster bind {addr}: {e}")))?,
        );
    }
    let actual: Vec<String> = listeners
        .iter()
        .map(|l| {
            l.local_addr()
                .map(|a| a.to_string())
                .map_err(|e| CmpcError::Io(format!("listener address: {e}")))
        })
        .collect::<Result<_>>()?;
    let mut pairs = Vec::with_capacity(manifest.n_nodes());
    let mut wire_handles = Vec::with_capacity(manifest.n_nodes());
    for (i, listener) in listeners.into_iter().enumerate() {
        let (t, e) =
            TcpTransport::from_listener(listener, actual.clone(), i, manifest.connect_timeout)?;
        wire_handles.push(t.clone());
        pairs.push((t, e));
    }
    let n = manifest.n_workers();
    let mut worker_handles = Vec::new();
    let mut source_handles = Vec::new();
    let mut master_pair = None;
    for (i, (t, e)) in pairs.into_iter().enumerate() {
        if i == manifest.master_id() {
            master_pair = Some((t, e));
            continue;
        }
        let mc = manifest.clone();
        let ch = chaos.clone();
        let handle = std::thread::Builder::new()
            .name(format!("cmpc-node-{i}"))
            .spawn(move || -> Result<()> {
                if i < n {
                    serve_worker_node(&mc, i, t, e, ch)
                } else {
                    serve_source_node(&mc, i == mc.source_a_id(), t, e, ch)
                }
            })
            .map_err(|e| CmpcError::Io(format!("spawning cluster node {i}: {e}")))?;
        if i < n {
            worker_handles.push(handle);
        } else {
            source_handles.push(handle);
        }
    }
    let (mt, me) = master_pair.expect("master slot present");
    let master_result = run_master_node(manifest, mt, me, chaos);
    // The master broadcast Shutdown (even on failure), so every node
    // thread unwinds; chaos-killed workers exited on their own.
    for h in worker_handles.into_iter().chain(source_handles) {
        let _ = h.join();
    }
    let mut wire = WireStats::default();
    for t in &wire_handles {
        wire.merge(&t.wire_stats());
    }
    let master = master_result?;
    Ok(ClusterReport { master, wire })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_derivations_are_deterministic() {
        let (a1, b1) = job_matrices(7, 3, 8);
        let (a2, b2) = job_matrices(7, 3, 8);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        let (a3, _) = job_matrices(7, 4, 8);
        assert_ne!(a1, a3, "different jobs must draw different data");
        assert_ne!(job_secret_seed(7, 0), job_secret_seed(7, 1));
        assert_eq!(digest_mat(&a1), digest_mat(&a2));
        assert_ne!(digest_mat(&a1), digest_mat(&a3));
    }

    #[test]
    fn role_parsing() {
        assert_eq!(
            NodeRole::parse("worker", Some(3)).unwrap(),
            NodeRole::Worker(3)
        );
        assert_eq!(NodeRole::parse("master", None).unwrap(), NodeRole::Master);
        assert!(NodeRole::parse("worker", None).is_err());
        assert!(NodeRole::parse("sourcer", None).is_err());
    }
}
