//! Per-link latency + bandwidth emulation — reproducible LAN/WAN edge
//! scenarios on any transport.
//!
//! A [`LinkShaper`] is an ordered list of [`ShapeRule`]s; the first rule
//! matching a data envelope's `(from, to, payload class)` assigns its link
//! a [`LinkSpec`]: propagation latency plus a token-bucket bandwidth model
//! (frames of `b` bytes depart when the bucket holds `b` tokens, refilled
//! at `rate_bytes_per_sec` up to `burst_bytes`; departures on one link are
//! FIFO). The modeled arrival time is `departure + latency`.
//!
//! Unlike `ProtocolConfig::link_delay` and the chaos
//! [`FaultAction::Delay`] — which sleep the **sending thread**, modeling a
//! busy peer — shaping delays the envelope *in flight*: the sender returns
//! immediately and the fabric's pump thread delivers at the modeled
//! arrival time. That distinction is load-bearing for the early-decode
//! fast path: a worker straggling behind a slow *link* is idle and
//! acknowledges a `JobAbort` instantly (exact overhead counters, no added
//! latency), whereas a *busy* worker cannot answer until it wakes.
//!
//! Shapers attach per deployment via
//! `ProtocolConfig::builder().shaper(...)`, per manifest via `shape` lines
//! (see [`crate::runtime::manifest::TopologyManifest`]), and compose with
//! the chaos harness: chaos decides *whether* an envelope survives, the
//! shaper decides *when* it arrives.
//!
//! [`FaultAction::Delay`]: crate::mpc::chaos::FaultAction::Delay

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mpc::chaos::PayloadClass;
use crate::mpc::network::NodeId;

/// The emulated characteristics of one link direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinkSpec {
    /// Propagation delay added to every frame.
    pub latency: Duration,
    /// Serialization rate in bytes/second; `0` = unlimited (latency only).
    pub rate_bytes_per_sec: u64,
    /// Token-bucket depth in bytes (how much can burst at line rate).
    pub burst_bytes: u64,
}

impl LinkSpec {
    /// Latency-only link (unlimited bandwidth).
    pub fn latency(latency: Duration) -> LinkSpec {
        LinkSpec {
            latency,
            rate_bytes_per_sec: 0,
            burst_bytes: 0,
        }
    }

    /// Full specification.
    pub fn new(latency: Duration, rate_bytes_per_sec: u64, burst_bytes: u64) -> LinkSpec {
        LinkSpec {
            latency,
            rate_bytes_per_sec,
            burst_bytes,
        }
    }

    /// A typical WAN edge link: 40 ms one-way, 100 Mbit/s, 64 KiB burst.
    pub fn wan() -> LinkSpec {
        LinkSpec::new(Duration::from_millis(40), 12_500_000, 64 * 1024)
    }

    /// A typical LAN link: 200 µs one-way, 1 Gbit/s, 256 KiB burst.
    pub fn lan() -> LinkSpec {
        LinkSpec::new(Duration::from_micros(200), 125_000_000, 256 * 1024)
    }
}

/// One link-matching rule (wildcards via `None`, same idiom as the chaos
/// harness's `FaultRule`). Earlier rules win.
#[derive(Clone, Copy, Debug)]
pub struct ShapeRule {
    from: Option<NodeId>,
    to: Option<NodeId>,
    class: Option<PayloadClass>,
    spec: LinkSpec,
}

impl ShapeRule {
    /// Shape every data envelope with `spec`; narrow with the builders.
    pub fn new(spec: LinkSpec) -> ShapeRule {
        ShapeRule {
            from: None,
            to: None,
            class: None,
            spec,
        }
    }

    /// Only envelopes sent by `node`.
    pub fn from_node(mut self, node: NodeId) -> Self {
        self.from = Some(node);
        self
    }

    /// Only envelopes addressed to `node`.
    pub fn to_node(mut self, node: NodeId) -> Self {
        self.to = Some(node);
        self
    }

    /// Only payloads of `class` (e.g. shape the bulky Phase-2 G-exchange
    /// while Phase-1 shares pass untouched).
    pub fn class(mut self, class: PayloadClass) -> Self {
        self.class = Some(class);
        self
    }

    fn matches(&self, from: NodeId, to: NodeId, class: PayloadClass) -> bool {
        let from_ok = match self.from {
            Some(n) => n == from,
            None => true,
        };
        let to_ok = match self.to {
            Some(n) => n == to,
            None => true,
        };
        let class_ok = match self.class {
            Some(c) => c == class,
            None => true,
        };
        from_ok && to_ok && class_ok
    }
}

/// Per-link token-bucket state.
struct Bucket {
    tokens: f64,
    last_refill: Instant,
    last_departure: Instant,
}

/// Ordered [`ShapeRule`]s plus the per-link bucket state they drive.
///
/// Bucket state is keyed by `(rule index, from, to)`: two class-specific
/// rules matching the same physical link model two independent queues
/// (each with its own rate/burst), rather than corrupting one bucket with
/// flip-flopping parameters.
#[derive(Default)]
pub struct LinkShaper {
    rules: Vec<ShapeRule>,
    buckets: Mutex<HashMap<(usize, NodeId, NodeId), Bucket>>,
}

impl std::fmt::Debug for LinkShaper {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LinkShaper")
            .field("rules", &self.rules)
            .finish()
    }
}

impl LinkShaper {
    /// A shaper with no rules (shapes nothing).
    pub fn new() -> LinkShaper {
        LinkShaper::default()
    }

    /// Append a rule (builder style; earlier rules win).
    pub fn rule(mut self, rule: ShapeRule) -> LinkShaper {
        self.rules.push(rule);
        self
    }

    /// Shape every link with one spec (the "whole deployment is on a WAN"
    /// convenience).
    pub fn all_links(spec: LinkSpec) -> LinkShaper {
        LinkShaper::new().rule(ShapeRule::new(spec))
    }

    /// Wrap for attachment to a `ProtocolConfig` / fabric tuning.
    pub fn into_shared(self) -> Arc<LinkShaper> {
        Arc::new(self)
    }

    /// The rules, in consult order.
    pub fn rules(&self) -> &[ShapeRule] {
        &self.rules
    }

    /// Modeled arrival instant for a `bytes`-byte frame sent now on
    /// `(from → to)`, or `None` when no rule matches (deliver inline).
    ///
    /// Mutates the link's token bucket: consumption is committed even
    /// though delivery happens later (the pump owns the wait).
    pub fn release_at(
        &self,
        from: NodeId,
        to: NodeId,
        class: PayloadClass,
        bytes: u64,
        now: Instant,
    ) -> Option<Instant> {
        let (rule_idx, rule) = self
            .rules
            .iter()
            .enumerate()
            .find(|(_, r)| r.matches(from, to, class))?;
        let spec = rule.spec;
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets.entry((rule_idx, from, to)).or_insert_with(|| Bucket {
            tokens: spec.burst_bytes as f64,
            last_refill: now,
            last_departure: now,
        });
        let mut departure = now;
        if spec.rate_bytes_per_sec > 0 {
            let rate = spec.rate_bytes_per_sec as f64;
            let dt = now.saturating_duration_since(b.last_refill).as_secs_f64();
            b.tokens = (b.tokens + dt * rate).min(spec.burst_bytes as f64);
            b.last_refill = now;
            // Token *debt* model: the balance may go negative — each
            // queued frame borrows against future refills, so back-to-back
            // sends serialize at exactly `rate` with up to `burst` of
            // slack.
            b.tokens -= bytes as f64;
            if b.tokens < 0.0 {
                departure = now + Duration::from_secs_f64(-b.tokens / rate);
            }
        }
        if departure < b.last_departure {
            departure = b.last_departure; // FIFO per link
        }
        b.last_departure = departure;
        let release = departure + spec.latency;
        if release <= now {
            None
        } else {
            Some(release)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GSHARE: PayloadClass = PayloadClass::GShare;

    #[test]
    fn no_rules_means_no_shaping() {
        let s = LinkShaper::new();
        assert!(s.release_at(0, 1, GSHARE, 1024, Instant::now()).is_none());
    }

    #[test]
    fn latency_only_rule_delays_matching_links() {
        let s = LinkShaper::new().rule(
            ShapeRule::new(LinkSpec::latency(Duration::from_millis(50))).to_node(3),
        );
        let now = Instant::now();
        let at = s.release_at(0, 3, GSHARE, 64, now).unwrap();
        assert!(at >= now + Duration::from_millis(50));
        // other destinations untouched
        assert!(s.release_at(0, 2, GSHARE, 64, now).is_none());
    }

    #[test]
    fn class_filter_narrows_the_match() {
        let s = LinkShaper::new().rule(
            ShapeRule::new(LinkSpec::latency(Duration::from_millis(10))).class(GSHARE),
        );
        let now = Instant::now();
        assert!(s.release_at(0, 1, GSHARE, 8, now).is_some());
        assert!(s
            .release_at(0, 1, PayloadClass::Shares, 8, now)
            .is_none());
    }

    #[test]
    fn token_bucket_serializes_beyond_the_burst() {
        // 1000 B/s, 100-byte burst: the first 100-byte frame departs at
        // once, the second waits ~100 ms, the third ~200 ms — FIFO.
        let s = LinkShaper::new().rule(ShapeRule::new(LinkSpec::new(
            Duration::ZERO,
            1000,
            100,
        )));
        let now = Instant::now();
        assert!(s.release_at(0, 1, GSHARE, 100, now).is_none()); // burst
        let second = s.release_at(0, 1, GSHARE, 100, now).unwrap();
        let third = s.release_at(0, 1, GSHARE, 100, now).unwrap();
        let d2 = second.saturating_duration_since(now);
        let d3 = third.saturating_duration_since(now);
        assert!(
            d2 >= Duration::from_millis(90) && d2 <= Duration::from_millis(110),
            "{d2:?}"
        );
        assert!(d3 >= d2 + Duration::from_millis(90), "{d3:?} vs {d2:?}");
        // independent link: its own bucket, full burst again
        assert!(s.release_at(5, 1, GSHARE, 100, now).is_none());
    }

    #[test]
    fn first_matching_rule_wins() {
        let s = LinkShaper::new()
            .rule(
                ShapeRule::new(LinkSpec::latency(Duration::from_millis(5))).from_node(1),
            )
            .rule(ShapeRule::new(LinkSpec::latency(Duration::from_millis(500))));
        let now = Instant::now();
        let at = s.release_at(1, 2, GSHARE, 8, now).unwrap();
        assert!(at < now + Duration::from_millis(100));
    }
}
