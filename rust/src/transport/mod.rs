//! Distributed edge transport: the pieces that take the fabric off the
//! in-process mpsc channels and onto real networks.
//!
//! * [`wire`] — the std-only framed wire codec: versioned magic +
//!   length-prefixed little-endian encoding of every
//!   [`crate::mpc::network::Envelope`], hardened against truncated,
//!   corrupt, and adversarial frames (typed errors, never panics, no
//!   unbounded allocations).
//! * [`tcp`] — a [`crate::mpc::network::Transport`] over `std::net`
//!   sockets: each party binds one listener, connects lazily to its peers
//!   per a [`crate::runtime::manifest::TopologyManifest`], and meters the
//!   bytes it actually puts on the wire per edge class.
//! * [`shaper`] — per-link latency + token-bucket bandwidth emulation,
//!   composable with both transports and with the chaos fault harness, so
//!   LAN vs WAN edge scenarios are reproducible in-tree.
//! * [`node`] — the multi-node runner behind `cmpc node`: one OS process
//!   (or thread) per party — worker / master / source-a / source-b —
//!   driving the existing `serve_worker` / `run_master` state machines
//!   over TCP, plus an in-process loopback cluster harness for tests and
//!   benches.

pub mod node;
pub mod shaper;
pub mod tcp;
pub mod wire;
