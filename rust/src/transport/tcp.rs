//! TCP transport: the fabric over `std::net` sockets, one process (or
//! thread) per party.
//!
//! Each party binds **one listener** at its manifest address and owns a
//! [`TcpTransport`] hosting its own node id. Outbound links are connected
//! lazily on first send (with retries up to `connect_timeout`, so peers
//! may start in any order); inbound connections need no handshake — every
//! frame carries its sender id, so the reader threads just decode frames
//! (via [`wire::FrameReader`], payload matrices loaned from the local
//! [`BufferPool`]) and push them onto the local node's receive queue. The
//! [`Endpoint`] handed to the node is the same mpsc-backed type the
//! in-process transport uses, so `serve_worker`, `run_master`, and the
//! `JobRouter` run unchanged over TCP.
//!
//! The transport meters every byte it actually writes, per edge class
//! ([`WireStats`]) — the measured on-wire form of the paper's ζ, asserted
//! against the analytical value in `tests/distributed.rs`.
//!
//! Inbound frames that fail to decode (corrupt, truncated, version skew)
//! terminate that connection and bump `decode_errors`; they can never
//! panic the process or allocate unboundedly (see [`wire`]).
//!
//! **Link liveness.** Every reader thread notes which sender ids its
//! connection carried; when the connection dies (EOF, reset, decode
//! error) those notes are withdrawn. A peer whose every noted connection
//! is gone reports `peer_alive == false` until it reconnects — the signal
//! the master's abort-ack drain uses to stop waiting on a crashed worker
//! whose last write landed in the OS buffer.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::metrics::{WireCounters, WireStats};
use crate::mpc::network::{BufferPool, Endpoint, Envelope, NodeId, Payload, Transport};
use crate::runtime::manifest::TopologyManifest;
use crate::transport::wire::{self, FrameReader};

/// Reader-side link-liveness book-keeping (see [`Transport::peer_alive`]).
///
/// `seen[n]` — whether node `n`'s envelopes were *ever* observed on an
/// inbound connection; `live[n]` — how many currently-open inbound
/// connections have carried them. A node is presumed alive until it has
/// been seen and every connection that saw it is gone; a reconnect
/// re-increments `live`, so a restarted peer is alive again on its first
/// frame.
struct Liveness {
    seen: Vec<AtomicBool>,
    live: Vec<AtomicUsize>,
}

impl Liveness {
    fn new(n_nodes: usize) -> Arc<Liveness> {
        Arc::new(Liveness {
            seen: (0..n_nodes).map(|_| AtomicBool::new(false)).collect(),
            live: (0..n_nodes).map(|_| AtomicUsize::new(0)).collect(),
        })
    }
}

/// One lazily-connected outbound link plus its reusable encode buffer.
struct PeerSlot {
    conn: Option<TcpStream>,
    /// Whether this link ever connected. First contact retries up to the
    /// connect budget (peers start in any order); *re*connects after a
    /// break are single-attempt, so sends to a peer that died cannot
    /// stall the caller for the whole budget (e.g. at teardown).
    ever_connected: bool,
    buf: Vec<u8>,
}

/// A [`Transport`] hosting one local node over TCP.
pub struct TcpTransport {
    local: NodeId,
    n_nodes: usize,
    addrs: Vec<String>,
    peers: Vec<Mutex<PeerSlot>>,
    /// The local node's receive queue. Behind a lock so
    /// `replace_endpoint` can swap it while reader threads hold clones of
    /// the lock, not of a stale sender.
    local_tx: Arc<RwLock<Sender<Envelope>>>,
    wire: Arc<WireCounters>,
    bufs: Arc<BufferPool>,
    connect_timeout: Duration,
    shutdown: Arc<AtomicBool>,
    listen_addr: SocketAddr,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
    /// Handles (`try_clone`) of every accepted inbound stream, so Drop can
    /// `shutdown()` them and the detached reader threads exit
    /// deterministically instead of lingering until the remote peer
    /// closes.
    accepted: Arc<Mutex<Vec<TcpStream>>>,
    /// Reader-side link-liveness (shared with the detached reader threads).
    liveness: Arc<Liveness>,
}

impl TcpTransport {
    /// Bind the local node's listener at `addrs[local]` and start
    /// accepting. Returns the transport and the local node's endpoint.
    pub fn bind(
        addrs: Vec<String>,
        local: NodeId,
        connect_timeout: Duration,
    ) -> Result<(Arc<TcpTransport>, Endpoint)> {
        if local >= addrs.len() {
            return Err(CmpcError::InvalidParams(format!(
                "local node {local} outside the {}-node topology",
                addrs.len()
            )));
        }
        let listener = TcpListener::bind(&addrs[local]).map_err(|e| {
            CmpcError::Io(format!("binding node {local} at {}: {e}", addrs[local]))
        })?;
        TcpTransport::from_listener(listener, addrs, local, connect_timeout)
    }

    /// [`TcpTransport::bind`] for a manifest-described topology.
    pub fn bind_manifest(
        manifest: &TopologyManifest,
        local: NodeId,
    ) -> Result<(Arc<TcpTransport>, Endpoint)> {
        TcpTransport::bind(manifest.addrs(), local, manifest.connect_timeout)
    }

    /// Wrap an **already bound** listener (the loopback cluster binds all
    /// listeners on port 0 first, then distributes the real addresses).
    pub fn from_listener(
        listener: TcpListener,
        addrs: Vec<String>,
        local: NodeId,
        connect_timeout: Duration,
    ) -> Result<(Arc<TcpTransport>, Endpoint)> {
        let n_nodes = addrs.len();
        let listen_addr = listener
            .local_addr()
            .map_err(|e| CmpcError::Io(format!("listener address: {e}")))?;
        let (tx, rx) = channel();
        let local_tx = Arc::new(RwLock::new(tx));
        let wire = Arc::new(WireCounters::default());
        let bufs = BufferPool::new();
        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(Mutex::new(Vec::new()));
        let liveness = Liveness::new(n_nodes);
        let accept = {
            let local_tx = local_tx.clone();
            let wire = wire.clone();
            let bufs = bufs.clone();
            let shutdown = shutdown.clone();
            let accepted = accepted.clone();
            let liveness = liveness.clone();
            std::thread::Builder::new()
                .name(format!("cmpc-tcp-accept-{local}"))
                .spawn(move || {
                    accept_loop(listener, local_tx, wire, bufs, shutdown, accepted, liveness)
                })
                .map_err(|e| CmpcError::Io(format!("spawning acceptor: {e}")))?
        };
        let transport = Arc::new(TcpTransport {
            local,
            n_nodes,
            addrs,
            peers: (0..n_nodes)
                .map(|_| {
                    Mutex::new(PeerSlot {
                        conn: None,
                        ever_connected: false,
                        buf: Vec::new(),
                    })
                })
                .collect(),
            local_tx,
            wire,
            bufs,
            connect_timeout,
            shutdown,
            listen_addr,
            accept_thread: Mutex::new(Some(accept)),
            accepted,
            liveness,
        });
        Ok((transport, Endpoint::new(local, rx)))
    }

    /// The bound listener address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.listen_addr
    }

    /// The node id this transport hosts.
    pub fn local_node(&self) -> NodeId {
        self.local
    }

    /// The payload buffer pool inbound matrices are loaned from — hand
    /// this to `serve_worker` so receive and compute share one pool.
    pub fn buffers(&self) -> &Arc<BufferPool> {
        &self.bufs
    }

    /// Single connection attempt (reconnects after a break).
    fn connect_once(&self, to: NodeId) -> Result<TcpStream> {
        let addr = &self.addrs[to];
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                Ok(s)
            }
            Err(e) => Err(CmpcError::Fabric(format!(
                "node {}: connecting to node {to} at {addr}: {e}",
                self.local
            ))),
        }
    }

    /// First contact: retry until the connect budget runs out (the peer
    /// process may not have bound its listener yet).
    fn connect(&self, to: NodeId) -> Result<TcpStream> {
        let deadline = Instant::now() + self.connect_timeout;
        loop {
            match self.connect_once(to) {
                Ok(s) => return Ok(s),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    fn meter(&self, env: &Envelope, to: NodeId, bytes: u64) {
        use Ordering::Relaxed;
        let n_workers = self.n_nodes.saturating_sub(3);
        let counter = match &env.payload {
            Payload::Control(_) => &self.wire.bytes_control,
            _ if env.from > n_workers && to < n_workers => &self.wire.bytes_source_to_worker,
            _ if env.from < n_workers && to < n_workers => &self.wire.bytes_worker_to_worker,
            _ if env.from < n_workers && to == n_workers => &self.wire.bytes_worker_to_master,
            // Data on a link the fabric would have rejected; count as
            // control rather than corrupt a ζ class.
            _ => &self.wire.bytes_control,
        };
        counter.fetch_add(bytes, Relaxed);
        self.wire.frames.fetch_add(1, Relaxed);
    }
}

fn accept_loop(
    listener: TcpListener,
    local_tx: Arc<RwLock<Sender<Envelope>>>,
    wire: Arc<WireCounters>,
    bufs: Arc<BufferPool>,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<Mutex<Vec<TcpStream>>>,
    liveness: Arc<Liveness>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shutdown.load(Ordering::Relaxed) {
                    return; // the Drop wake-up connection
                }
                let _ = stream.set_nodelay(true);
                if let Ok(handle) = stream.try_clone() {
                    accepted.lock().unwrap().push(handle);
                }
                let tx = local_tx.clone();
                let wire = wire.clone();
                let bufs = bufs.clone();
                let liveness = liveness.clone();
                // Reader threads exit on peer EOF / decode error; they
                // hold no Arc back to the transport, so teardown order is
                // acyclic.
                let _ = std::thread::Builder::new()
                    .name("cmpc-tcp-rx".to_string())
                    .spawn(move || reader_loop(stream, tx, wire, bufs, liveness));
            }
            Err(_) => {
                if shutdown.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn reader_loop(
    stream: TcpStream,
    local_tx: Arc<RwLock<Sender<Envelope>>>,
    wire: Arc<WireCounters>,
    bufs: Arc<BufferPool>,
    liveness: Arc<Liveness>,
) {
    // Sender ids this connection has carried (almost always exactly one).
    let mut noted: Vec<NodeId> = Vec::new();
    read_frames(stream, &local_tx, &wire, &bufs, &liveness, &mut noted);
    // The connection is gone — however it died, the peers it carried have
    // one fewer live inbound link. When a peer's count reaches zero it
    // reads as dead ([`Transport::peer_alive`]) until it reconnects.
    for &from in &noted {
        liveness.live[from].fetch_sub(1, Ordering::Relaxed);
    }
}

fn read_frames(
    stream: TcpStream,
    local_tx: &Arc<RwLock<Sender<Envelope>>>,
    wire: &Arc<WireCounters>,
    bufs: &Arc<BufferPool>,
    liveness: &Arc<Liveness>,
    noted: &mut Vec<NodeId>,
) {
    let mut reader = std::io::BufReader::new(stream);
    let mut frames = FrameReader::new();
    loop {
        match frames.read_from(&mut reader, Some(bufs)) {
            Ok(Some(env)) => {
                if env.from < liveness.seen.len() && !noted.contains(&env.from) {
                    noted.push(env.from);
                    liveness.seen[env.from].store(true, Ordering::Relaxed);
                    liveness.live[env.from].fetch_add(1, Ordering::Relaxed);
                }
                let tx = local_tx.read().unwrap().clone();
                if tx.send(env).is_err() {
                    return; // local node gone; stop draining the socket
                }
            }
            Ok(None) => return, // clean EOF: peer closed
            Err(_) => {
                // Corrupt or truncated frame: this connection can no
                // longer be framed — drop it. The peer re-connects if it
                // is still alive; persistent garbage shows up here.
                wire.decode_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
    }
}

impl Transport for TcpTransport {
    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn deliver(&self, to: NodeId, env: Envelope) -> Result<()> {
        if to >= self.n_nodes {
            return Err(CmpcError::Fabric(format!(
                "send to nonexistent node {to} ({}-node topology)",
                self.n_nodes
            )));
        }
        if to == self.local {
            // Self-delivery never touches the wire.
            let tx = self.local_tx.read().unwrap().clone();
            return tx.send(env).map_err(|_| {
                CmpcError::Fabric(format!("node {to}: local endpoint dropped"))
            });
        }
        let mut slot = self.peers[to].lock().unwrap();
        if slot.conn.is_none() {
            let stream = if slot.ever_connected {
                self.connect_once(to)?
            } else {
                self.connect(to)?
            };
            slot.conn = Some(stream);
            slot.ever_connected = true;
        }
        let PeerSlot { conn, buf, .. } = &mut *slot;
        let stream = conn.as_mut().expect("connected above");
        match wire::write_envelope(stream, &env, buf) {
            Ok(n) => {
                self.meter(&env, to, n as u64);
                Ok(())
            }
            Err(e) => {
                // Connection is unusable; a later send may reconnect (the
                // peer could have restarted).
                *conn = None;
                Err(e)
            }
        }
    }

    /// Coalesced delivery: every envelope is encoded back-to-back into the
    /// peer slot's buffer and flushed with **one** `write_all` — one
    /// syscall (and, with Nagle disabled, typically one TCP segment) for
    /// the whole batch instead of one per envelope. Metering stays
    /// per-envelope: each frame is classified and counted exactly as a
    /// sequential [`TcpTransport::deliver`] would have, so `WireStats`
    /// (frames *and* per-class bytes) is byte-identical either way.
    fn deliver_batch(&self, to: NodeId, envs: Vec<Envelope>) -> Result<()> {
        use std::io::Write;
        if envs.len() <= 1 {
            return match envs.into_iter().next() {
                Some(env) => self.deliver(to, env),
                None => Ok(()),
            };
        }
        if to >= self.n_nodes {
            return Err(CmpcError::Fabric(format!(
                "send to nonexistent node {to} ({}-node topology)",
                self.n_nodes
            )));
        }
        if to == self.local {
            let tx = self.local_tx.read().unwrap().clone();
            for env in envs {
                tx.send(env).map_err(|_| {
                    CmpcError::Fabric(format!("node {to}: local endpoint dropped"))
                })?;
            }
            return Ok(());
        }
        // Enforce the frame cap up front (write_envelope does this per
        // frame on the sequential path) so an oversized envelope rejects
        // the batch before any bytes hit the wire.
        for env in &envs {
            let payload_len = wire::frame_len(env) - wire::HEADER_LEN;
            if payload_len > wire::MAX_FRAME_PAYLOAD {
                return Err(CmpcError::Fabric(format!(
                    "wire: refusing to send a {payload_len}-byte payload \
                     (cap {} bytes; partition the job smaller)",
                    wire::MAX_FRAME_PAYLOAD
                )));
            }
        }
        let mut slot = self.peers[to].lock().unwrap();
        if slot.conn.is_none() {
            let stream = if slot.ever_connected {
                self.connect_once(to)?
            } else {
                self.connect(to)?
            };
            slot.conn = Some(stream);
            slot.ever_connected = true;
        }
        let PeerSlot { conn, buf, .. } = &mut *slot;
        let stream = conn.as_mut().expect("connected above");
        buf.clear();
        let mut frame_bytes = Vec::with_capacity(envs.len());
        for env in &envs {
            let start = buf.len();
            wire::encode_envelope(env, buf);
            frame_bytes.push((buf.len() - start) as u64);
        }
        match stream.write_all(buf) {
            Ok(()) => {
                for (env, n) in envs.iter().zip(frame_bytes) {
                    self.meter(env, to, n);
                }
                Ok(())
            }
            Err(e) => {
                *conn = None;
                Err(CmpcError::Fabric(format!("wire write: {e}")))
            }
        }
    }

    fn replace_endpoint(&self, node: NodeId) -> Result<Endpoint> {
        if node != self.local {
            return Err(CmpcError::Fabric(format!(
                "node {node} is remote; only the local node {} can be re-endpointed",
                self.local
            )));
        }
        let (tx, rx) = channel();
        *self.local_tx.write().unwrap() = tx;
        Ok(Endpoint::new(node, rx))
    }

    fn wire_stats(&self) -> WireStats {
        self.wire.snapshot()
    }

    fn peer_alive(&self, node: NodeId) -> bool {
        if node >= self.n_nodes {
            return true; // no evidence either way; sends will error anyway
        }
        // Alive until observed dead: never seen, or at least one inbound
        // connection that carried this peer's envelopes is still open.
        !self.liveness.seen[node].load(Ordering::Relaxed)
            || self.liveness.live[node].load(Ordering::Relaxed) > 0
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Wake the acceptor with a throwaway connection so it observes the
        // flag and exits.
        let _ = TcpStream::connect(self.listen_addr);
        if let Some(h) = self.accept_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        // Shut down every accepted inbound stream: the detached reader
        // threads see EOF at once and exit instead of lingering (with
        // their sockets) until the remote peer happens to close.
        for stream in self.accepted.lock().unwrap().drain(..) {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::FpMat;
    use crate::mpc::network::{ControlMsg, PooledMat};
    use crate::util::rng::ChaChaRng;

    /// Bind a 4-node loopback topology (1 worker + master + 2 sources)
    /// and return transports for the first `live` nodes.
    fn loopback(live: usize) -> (Vec<Arc<TcpTransport>>, Vec<Endpoint>) {
        let listeners: Vec<TcpListener> = (0..4)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let addrs: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let mut transports = Vec::new();
        let mut endpoints = Vec::new();
        for (i, listener) in listeners.into_iter().enumerate().take(live) {
            let (t, e) =
                TcpTransport::from_listener(listener, addrs.clone(), i, Duration::from_secs(5))
                    .unwrap();
            transports.push(t);
            endpoints.push(e);
        }
        (transports, endpoints)
    }

    #[test]
    fn envelopes_cross_real_sockets() {
        let (transports, endpoints) = loopback(2);
        let mut rng = ChaChaRng::seed_from_u64(3);
        let m = FpMat::random(&mut rng, 4, 4);
        // worker 0 → master (node 1)
        transports[0]
            .deliver(
                1,
                Envelope {
                    job: 9,
                    from: 0,
                    payload: Payload::IShare(PooledMat::detached(m.clone())),
                },
            )
            .unwrap();
        let env = endpoints[1].recv().unwrap();
        assert_eq!(env.job, 9);
        assert_eq!(env.from, 0);
        match env.payload {
            Payload::IShare(got) => assert_eq!(*got, m),
            other => panic!("unexpected {other:?}"),
        }
        let stats = transports[0].wire_stats();
        assert_eq!(stats.frames, 1);
        assert!(stats.bytes_worker_to_master > 0);
        assert_eq!(stats.decode_errors, 0);
        // the receiving side loaned the payload from its pool
        drop(env);
    }

    /// A coalesced batch (one socket write) must meter exactly like the
    /// same envelopes sent one `deliver` at a time: same frame count, same
    /// per-class byte totals, same arrival order.
    #[test]
    fn deliver_batch_meters_per_envelope_like_sequential() {
        let mut rng = ChaChaRng::seed_from_u64(11);
        let m = FpMat::random(&mut rng, 4, 4);
        let make = |job| {
            vec![
                Envelope {
                    job,
                    from: 0,
                    payload: Payload::IShare(PooledMat::detached(m.clone())),
                },
                Envelope {
                    job,
                    from: 0,
                    payload: Payload::Control(ControlMsg::JobDone {
                        mults: 3,
                        stored: 4,
                    }),
                },
            ]
        };

        let (batched, endpoints) = loopback(2);
        batched[0].deliver_batch(1, make(5)).unwrap();
        let first = endpoints[1].recv().unwrap();
        match first.payload {
            Payload::IShare(got) => assert_eq!(*got, m),
            other => panic!("expected IShare first, got {other:?}"),
        }
        let second = endpoints[1].recv().unwrap();
        match second.payload {
            Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                assert_eq!((mults, stored), (3, 4));
            }
            other => panic!("expected JobDone second, got {other:?}"),
        }
        let got = batched[0].wire_stats();
        assert_eq!(got.frames, 2, "metering must stay per-envelope");

        let (sequential, seq_endpoints) = loopback(2);
        for env in make(5) {
            sequential[0].deliver(1, env).unwrap();
        }
        seq_endpoints[1].recv().unwrap();
        seq_endpoints[1].recv().unwrap();
        let want = sequential[0].wire_stats();
        assert_eq!(got.frames, want.frames);
        assert_eq!(got.bytes_worker_to_master, want.bytes_worker_to_master);
        assert_eq!(got.bytes_control, want.bytes_control);
        assert_eq!(got.bytes_worker_to_worker, want.bytes_worker_to_worker);
        assert_eq!(got.bytes_source_to_worker, want.bytes_source_to_worker);
    }

    #[test]
    fn dead_peer_is_a_typed_error_and_garbage_is_contained() {
        let (transports, endpoints) = loopback(1);
        // A peer address where nothing listens: bind, learn the port, drop.
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let own = TcpListener::bind("127.0.0.1:0").unwrap();
        let own_addr = own.local_addr().unwrap().to_string();
        let (t, _e) = TcpTransport::from_listener(
            own,
            vec![own_addr, dead_addr],
            0,
            Duration::from_millis(200),
        )
        .unwrap();
        let err = t
            .deliver(
                1,
                Envelope {
                    job: 0,
                    from: 0,
                    payload: Payload::IShare(PooledMat::detached(FpMat::zeros(1, 1))),
                },
            )
            .unwrap_err();
        assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
        drop(t);

        // Garbage into our listener: decode error counted, process fine.
        let mut s = TcpStream::connect(transports[0].local_addr()).unwrap();
        use std::io::Write;
        s.write_all(b"this is not a cmpc frame at all................").unwrap();
        drop(s);
        let t0 = Instant::now();
        while transports[0].wire_stats().decode_errors == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "decode error not counted");
            std::thread::sleep(Duration::from_millis(5));
        }
        // endpoint got nothing
        assert!(endpoints[0]
            .recv_timeout(Duration::from_millis(50))
            .is_err());
    }

    #[test]
    fn link_liveness_tracks_reader_side_disconnects() {
        let (mut transports, endpoints) = loopback(2);
        // No evidence yet: peers are presumed alive.
        assert!(transports[1].peer_alive(0));
        // worker 0 → master (node 1): once the frame lands, node 1 has
        // seen node 0 on a live inbound connection.
        transports[0]
            .deliver(
                1,
                Envelope {
                    job: 1,
                    from: 0,
                    payload: Payload::Control(ControlMsg::JobDone {
                        mults: 0,
                        stored: 0,
                    }),
                },
            )
            .unwrap();
        endpoints[1].recv().unwrap();
        assert!(transports[1].peer_alive(0));
        // Kill node 0: its outbound socket closes with its transport, node
        // 1's reader hits EOF, and the last live connection that carried
        // node 0 goes away — peer_alive flips without any send attempt.
        let t0_transport = transports.remove(0);
        drop(t0_transport);
        let deadline = Instant::now();
        while transports[0].peer_alive(0) {
            assert!(
                deadline.elapsed() < Duration::from_secs(5),
                "peer 0 never read as dead after its transport dropped"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // An unrelated peer that was never heard from stays presumed alive.
        assert!(transports[0].peer_alive(2));
    }
}
