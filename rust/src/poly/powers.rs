//! Power-set algebra for polynomial supports (eq. 1–3 of the paper).
//!
//! `P(f)` — the set of exponents with nonzero coefficients — is represented as
//! a sorted `Vec<u64>`. Sumsets `A + B = {a + b}` are the workhorse of the
//! worker-count analysis: eq. (23) says the required number of workers equals
//! `|(P(C_A) ∪ P(S_A)) + (P(C_B) ∪ P(S_B))|`. For the sweep sizes in Fig. 2
//! the bitset implementation below computes a sumset in ~|A|·(max/64) word
//! operations.

/// A polynomial support: strictly increasing exponents.
pub type PowerSet = Vec<u64>;

/// Largest element, or None for an empty set.
pub fn max_power(a: &PowerSet) -> Option<u64> {
    a.last().copied()
}

/// Sorted union of two supports.
pub fn union(a: &PowerSet, b: &PowerSet) -> PowerSet {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Fixed-capacity bitset over `0..len`.
pub struct BitSet {
    words: Vec<u64>,
    len: u64,
}

impl BitSet {
    /// Empty set with capacity for members `0..len`.
    pub fn new(len: u64) -> BitSet {
        BitSet {
            words: vec![0; (len as usize + 63) / 64],
            len,
        }
    }

    /// Add `i` to the set (`i` must be `< len`).
    #[inline]
    pub fn insert(&mut self, i: u64) {
        debug_assert!(i < self.len);
        self.words[(i / 64) as usize] |= 1u64 << (i % 64);
    }

    /// Membership test (out-of-range `i` is simply absent).
    #[inline]
    pub fn contains(&self, i: u64) -> bool {
        i < self.len && self.words[(i / 64) as usize] & (1u64 << (i % 64)) != 0
    }

    /// Number of members currently in the set.
    pub fn count(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// `self |= other << shift` — the inner step of the sumset kernel.
    pub fn or_shifted(&mut self, other: &BitSet, shift: u64) {
        let word_shift = (shift / 64) as usize;
        let bit_shift = shift % 64;
        let n = self.words.len();
        if bit_shift == 0 {
            for (i, &w) in other.words.iter().enumerate() {
                let d = i + word_shift;
                if d < n {
                    self.words[d] |= w;
                }
            }
        } else {
            for (i, &w) in other.words.iter().enumerate() {
                let d = i + word_shift;
                if d < n {
                    self.words[d] |= w << bit_shift;
                }
                if d + 1 < n {
                    self.words[d + 1] |= w >> (64 - bit_shift);
                }
            }
        }
    }

    /// Iterate set bits in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as u64;
                    bits &= bits - 1;
                    Some(wi as u64 * 64 + b)
                }
            })
        })
    }
}

/// Sumset `A + B` as a sorted vector.
pub fn sumset(a: &PowerSet, b: &PowerSet) -> PowerSet {
    sumset_bits(a, b).iter().collect()
}

/// `|A + B|` — the worker-count kernel of eq. (23).
pub fn sumset_size(a: &PowerSet, b: &PowerSet) -> u64 {
    sumset_bits(a, b).count()
}

fn sumset_bits(a: &PowerSet, b: &PowerSet) -> BitSet {
    let (amax, bmax) = match (max_power(a), max_power(b)) {
        (Some(x), Some(y)) => (x, y),
        _ => return BitSet::new(1),
    };
    let cap = amax + bmax + 1;
    let mut bbits = BitSet::new(bmax + 1);
    for &e in b {
        bbits.insert(e);
    }
    let mut out = BitSet::new(cap);
    for &e in a {
        out.or_shifted(&bbits, e);
    }
    out
}

/// The `z` smallest non-negative integers not contained in `forbidden`
/// (which must be sorted). This is the greedy secret-power selection shared
/// by Algorithm 1 and Algorithm 2: pick minimal powers whose cross terms
/// avoid the important powers.
pub fn smallest_excluding(z: usize, forbidden: &PowerSet) -> PowerSet {
    let mut out = Vec::with_capacity(z);
    let mut fi = 0usize;
    let mut x = 0u64;
    while out.len() < z {
        while fi < forbidden.len() && forbidden[fi] < x {
            fi += 1;
        }
        if fi < forbidden.len() && forbidden[fi] == x {
            fi += 1;
        } else {
            out.push(x);
        }
        x += 1;
    }
    out
}

/// All non-negative differences `{u - c : u ∈ us, c ∈ cs, u ≥ c}`, sorted and
/// deduplicated — the "forbidden" set for greedy secret-power selection
/// (a secret power `e` with `e + c = u` would collide garbage with an
/// important term).
pub fn nonneg_differences(us: &PowerSet, cs: &PowerSet) -> PowerSet {
    let mut out: Vec<u64> = Vec::with_capacity(us.len() * cs.len());
    for &u in us {
        for &c in cs {
            if u >= c {
                out.push(u - c);
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;
    use std::collections::BTreeSet;

    fn naive_sumset(a: &PowerSet, b: &PowerSet) -> PowerSet {
        let mut s = BTreeSet::new();
        for &x in a {
            for &y in b {
                s.insert(x + y);
            }
        }
        s.into_iter().collect()
    }

    #[test]
    fn sumset_matches_naive() {
        property("sumset == naive", 300, |rng| {
            let na = rng.gen_index(20) + 1;
            let nb = rng.gen_index(20) + 1;
            let mut a: Vec<u64> = (0..na).map(|_| rng.gen_range(200)).collect();
            let mut b: Vec<u64> = (0..nb).map(|_| rng.gen_range(200)).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let fast = sumset(&a, &b);
            let slow = naive_sumset(&a, &b);
            if fast != slow {
                return Err(format!("a={a:?} b={b:?}"));
            }
            if sumset_size(&a, &b) != slow.len() as u64 {
                return Err("size mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn union_matches_btreeset() {
        property("union == set union", 200, |rng| {
            let mut a: Vec<u64> = (0..rng.gen_index(15)).map(|_| rng.gen_range(50)).collect();
            let mut b: Vec<u64> = (0..rng.gen_index(15)).map(|_| rng.gen_range(50)).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let expect: Vec<u64> = a
                .iter()
                .chain(b.iter())
                .copied()
                .collect::<BTreeSet<_>>()
                .into_iter()
                .collect();
            if union(&a, &b) != expect {
                return Err("union".into());
            }
            Ok(())
        });
    }

    #[test]
    fn smallest_excluding_greedy() {
        assert_eq!(smallest_excluding(3, &vec![0, 1, 2]), vec![3, 4, 5]);
        assert_eq!(smallest_excluding(3, &vec![1, 3]), vec![0, 2, 4]);
        assert_eq!(smallest_excluding(2, &vec![]), vec![0, 1]);
        property("smallest_excluding avoids forbidden", 200, |rng| {
            let mut forbidden: Vec<u64> =
                (0..rng.gen_index(30)).map(|_| rng.gen_range(40)).collect();
            forbidden.sort_unstable();
            forbidden.dedup();
            let z = rng.gen_index(10) + 1;
            let got = smallest_excluding(z, &forbidden);
            if got.len() != z {
                return Err("wrong count".into());
            }
            for &g in &got {
                if forbidden.binary_search(&g).is_ok() {
                    return Err(format!("{g} is forbidden"));
                }
            }
            // minimality: everything below max(got) that is not forbidden is in got
            let maxg = *got.last().unwrap();
            for x in 0..maxg {
                if forbidden.binary_search(&x).is_err() && got.binary_search(&x).is_err() {
                    return Err(format!("{x} skipped"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nonneg_differences_basic() {
        let us = vec![5, 7];
        let cs = vec![1, 6];
        // 5-1=4, 7-1=6, 7-6=1; 5-6 negative dropped
        assert_eq!(nonneg_differences(&us, &cs), vec![1, 4, 6]);
    }

    #[test]
    fn bitset_iter_roundtrip() {
        let mut bs = BitSet::new(200);
        for &v in &[0u64, 1, 63, 64, 65, 127, 128, 199] {
            bs.insert(v);
        }
        let got: Vec<u64> = bs.iter().collect();
        assert_eq!(got, vec![0, 1, 63, 64, 65, 127, 128, 199]);
        assert_eq!(bs.count(), 8);
    }
}
