//! Polynomial machinery: power-set algebra, sparse matrix-coefficient
//! polynomials, Lagrange interpolation and generalized-Vandermonde solves
//! over `GF(p)`.
//!
//! The CMPC constructions are defined entirely by *which powers of `x`* carry
//! coded blocks, secret blocks, and garbage cross terms; [`powers`] provides
//! the set algebra of eq. (1)–(3) (`P(f)`, sumsets `A+B`). [`MatPoly`] is the
//! share-generating polynomial `F(x) = C(x) + S(x)` with matrix coefficients,
//! and [`interp`] provides the two reconstruction primitives:
//!
//! * dense Lagrange interpolation for Phase 3 (`I(x)` has full support
//!   `0..t²+z`), and
//! * the generalized Vandermonde solve producing the `rₙ^{(i,l)}`
//!   coefficients of eq. (18) from the sparse support of `H(x)`.

pub mod interp;
pub mod matpoly;
pub mod powers;

pub use interp::{lagrange_interpolate, vandermonde_inverse_rows};
pub use matpoly::MatPoly;
pub use powers::{max_power, sumset, sumset_size, PowerSet};
