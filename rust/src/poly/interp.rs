//! Reconstruction primitives over `GF(p)`.
//!
//! * [`lagrange_interpolate`] — dense interpolation used by the master in
//!   Phase 3: `I(x)` has degree `t²+z−1` and full support, so any `t²+z`
//!   evaluations reconstruct all coefficients (this is the straggler
//!   tolerance: the master uses the *first* `t²+z` arrivals).
//! * [`vandermonde_inverse_rows`] — the generalized-Vandermonde solve that
//!   yields the `rₙ^{(i,l)}` combination coefficients of eq. (18): `H(x)` has
//!   sparse support `{e₁..e_N}`, each worker holds `H(αₙ)`, and
//!   `coeff_{e_j} = Σₙ rows[j][n] · H(αₙ)`.

use crate::error::{CmpcError, Result};
use crate::ff::{self, P};

/// Interpolate the dense coefficient vector of the unique polynomial of
/// degree `< points.len()` through `(x_i, y_i)`.
///
/// O(k²) Newton-style construction; `k = t²+z` stays small (≤ a few hundred).
///
/// # Panics
/// Panics if evaluation points repeat.
pub fn lagrange_interpolate(points: &[(u64, u64)]) -> Vec<u64> {
    let k = points.len();
    assert!(k > 0);
    // coeffs of the running interpolant, and of the running nodal polynomial
    // prod (x - x_i)
    let mut coeffs = vec![0u64; k];
    let mut nodal = vec![0u64; k + 1];
    nodal[0] = 1;
    let mut nodal_deg = 0usize;
    for (i, &(xi, yi)) in points.iter().enumerate() {
        // value of current interpolant at xi
        let mut acc = 0u64;
        let mut xp = 1u64;
        for &c in coeffs.iter().take(i) {
            acc = ff::add(acc, ff::mul(c, xp));
            xp = ff::mul(xp, xi);
        }
        // value of nodal polynomial at xi
        let mut nv = 0u64;
        let mut xp = 1u64;
        for &c in nodal.iter().take(nodal_deg + 1) {
            nv = ff::add(nv, ff::mul(c, xp));
            xp = ff::mul(xp, xi);
        }
        assert!(nv != 0, "repeated evaluation point {xi}");
        let delta = ff::mul(ff::sub(yi, acc), ff::inv(nv));
        // interpolant += delta * nodal
        for j in 0..=nodal_deg {
            coeffs[j] = ff::add(coeffs[j], ff::mul(delta, nodal[j]));
        }
        // nodal *= (x - xi)
        if i + 1 < k {
            let neg_xi = ff::neg(xi);
            for j in (0..=nodal_deg).rev() {
                let v = nodal[j];
                nodal[j + 1] = ff::add(nodal[j + 1], v);
                nodal[j] = ff::mul(v, neg_xi);
            }
            nodal_deg += 1;
        }
    }
    coeffs
}

/// Rows of the inverse of the generalized Vandermonde matrix
/// `M[n][j] = αₙ^{e_j}`.
///
/// Returns `rows` with `rows[j][n]` such that for any polynomial
/// `H(x) = Σ_j c_j x^{e_j}`: `c_j = Σₙ rows[j][n] · H(αₙ)`.
///
/// Gaussian elimination over `GF(p)`, O(N³); the coordinator computes this
/// once per (scheme, α-assignment) and caches it ("known by all workers",
/// Algorithm 3 line 2).
///
/// Unlike the classic Vandermonde (support `0..n`), a *generalized*
/// Vandermonde over `GF(p)` can be singular for specific α choices even with
/// distinct nonzero αs (its determinant is a Schur polynomial that may vanish
/// mod p). Returns `None` in that case — callers re-draw αs
/// ([`choose_alphas`]).
///
/// # Panics
/// Panics if `alphas.len() != support.len()`.
pub fn try_vandermonde_inverse_rows(alphas: &[u64], support: &[u64]) -> Option<Vec<Vec<u64>>> {
    let n = alphas.len();
    assert_eq!(
        n,
        support.len(),
        "need exactly |support| evaluation points"
    );
    // Build [M | I] and reduce. aug[r] has 2n entries.
    let mut aug: Vec<Vec<u64>> = (0..n)
        .map(|r| {
            let mut row: Vec<u64> = support.iter().map(|&e| ff::pow(alphas[r], e)).collect();
            row.extend((0..n).map(|c| u64::from(c == r)));
            row
        })
        .collect();
    for col in 0..n {
        // pivot
        let piv = (col..n).find(|&r| aug[r][col] != 0)?;
        aug.swap(col, piv);
        let inv_p = ff::inv(aug[col][col]);
        for v in aug[col].iter_mut() {
            *v = ff::mul(*v, inv_p);
        }
        let pivot_row = aug[col].clone();
        for (r, row) in aug.iter_mut().enumerate() {
            if r != col && row[col] != 0 {
                let f = row[col];
                for (v, &pv) in row.iter_mut().zip(pivot_row.iter()) {
                    *v = ff::sub(*v, ff::mul(f, pv));
                }
            }
        }
    }
    // M^{-1} columns live in the right half; rows[j][n] = (M^{-1})[j][n].
    Some(
        (0..n)
            .map(|j| (0..n).map(|r| aug[j][n + r]).collect())
            .collect(),
    )
}

/// Infallible wrapper for supports known to be safe (dense `0..n` classic
/// Vandermonde with distinct points is always invertible).
pub fn vandermonde_inverse_rows(alphas: &[u64], support: &[u64]) -> Vec<Vec<u64>> {
    try_vandermonde_inverse_rows(alphas, support)
        .expect("singular Vandermonde — repeated evaluation points?")
}

/// Evaluate a dense coefficient vector at `x` (Horner).
fn eval_dense(coeffs: &[u64], x: u64) -> u64 {
    let mut acc = 0u64;
    for &c in coeffs.iter().rev() {
        acc = ff::add(ff::mul(acc, x), c);
    }
    acc
}

/// Locate up to `a` corrupted evaluations of a polynomial of degree
/// `< k_dim` — the error-locator pass of Byzantine-robust reconstruction.
///
/// `points` are `(x, y)` pairs of which at most `a` may carry a wrong `y`.
/// Decoding is **Berlekamp–Welch**: solve the linear system
/// `Q(xᵢ) = yᵢ·E(xᵢ)` for a monic error-locator `E` of degree
/// `e = min(a, (len − k_dim)/2)` and a numerator `Q` of degree
/// `< k_dim + e` (one Gaussian elimination, `O(len³)` — polynomial in
/// every parameter, unlike a subset search, so a large fleet with a large
/// error budget cannot stall the master combinatorially). Any solution
/// yields the unique codeword `f = Q/E` within the unique-decoding
/// radius; the blamed set is exactly the points where `yᵢ ≠ f(xᵢ)`.
///
/// Soundness needs the caller to supply `points.len() ≥ k_dim + 2a`
/// (the Reed–Solomon unique-decoding bound): then at most `a` wrong
/// points leave `≥ k_dim + a` agreeing ones, which pin `f` uniquely.
/// When the surplus is smaller, the effective radius `e` shrinks with it
/// rather than risking an ambiguous (unsound) exclusion.
///
/// Returns the blamed indices into `points` (empty when every point is
/// consistent), or `None` when no polynomial of degree `< k_dim` agrees
/// with all but `≤ e` points — more corruptions than the radius covers.
pub fn locate_corrupt_evaluations(
    points: &[(u64, u64)],
    k_dim: usize,
    a: usize,
) -> Option<Vec<usize>> {
    let n = points.len();
    if n < k_dim || k_dim == 0 {
        return None;
    }
    let e = a.min((n - k_dim) / 2);
    // Unknowns: q₀..q_{k_dim+e−1}, then e₀..e_{e−1} (E is monic of degree
    // exactly e, so its top coefficient is fixed at 1 and moved to the
    // right-hand side): row i reads
    //   Σ_j qⱼ·xᵢʲ − yᵢ·Σ_{j<e} eⱼ·xᵢʲ = yᵢ·xᵢᵉ.
    let cols = k_dim + 2 * e;
    let mut aug: Vec<Vec<u64>> = points
        .iter()
        .map(|&(x, y)| {
            let mut row = Vec::with_capacity(cols + 1);
            let mut xp = 1u64;
            for _ in 0..k_dim + e {
                row.push(xp);
                xp = ff::mul(xp, x);
            }
            let mut xp = 1u64;
            for _ in 0..e {
                row.push(ff::neg(ff::mul(y, xp)));
                xp = ff::mul(xp, x);
            }
            row.push(ff::mul(y, xp)); // xp = xᵉ after the loop
            row
        })
        .collect();
    // Row-reduce; free variables are set to 0 (with ≤ e true errors the
    // system is consistent and *any* solution gives the same ratio Q/E —
    // two solutions satisfy Q₁E₂ = Q₂E₁ at n ≥ k_dim+2e points, which
    // exceeds the product's degree, so they are equal as polynomials).
    let mut pivot_of_col: Vec<Option<usize>> = vec![None; cols];
    let mut rank = 0usize;
    for c in 0..cols {
        if rank >= n {
            break;
        }
        let Some(piv) = (rank..n).find(|&i| aug[i][c] != 0) else {
            continue;
        };
        aug.swap(rank, piv);
        let inv = ff::inv(aug[rank][c]);
        for v in aug[rank].iter_mut() {
            *v = ff::mul(*v, inv);
        }
        let prow = aug[rank].clone();
        for (i, row) in aug.iter_mut().enumerate() {
            if i != rank && row[c] != 0 {
                let f = row[c];
                for (v, &pv) in row.iter_mut().zip(prow.iter()) {
                    *v = ff::sub(*v, ff::mul(f, pv));
                }
            }
        }
        pivot_of_col[c] = Some(rank);
        rank += 1;
    }
    // A zeroed row with a nonzero right-hand side means no (Q, E) exists:
    // more than e corruptions.
    if aug[rank..].iter().any(|row| row[cols] != 0) {
        return None;
    }
    let mut sol = vec![0u64; cols];
    for (c, piv) in pivot_of_col.iter().enumerate() {
        if let Some(r) = *piv {
            sol[c] = aug[r][cols];
        }
    }
    let mut e_coeffs = sol[k_dim + e..].to_vec();
    e_coeffs.push(1); // monic xᵉ
    let f = poly_div_exact(&sol[..k_dim + e], &e_coeffs)?;
    let blamed: Vec<usize> = points
        .iter()
        .enumerate()
        .filter(|&(_, &(x, y))| eval_dense(&f, x) != y)
        .map(|(i, _)| i)
        .collect();
    // Beyond the radius the division can still come out exact on an
    // aligned draw; the agreement count is the decoder's real acceptance
    // test.
    if blamed.len() > e {
        return None;
    }
    Some(blamed)
}

/// Exact polynomial division `num / den` over `GF(p)` for a monic `den`;
/// `None` when the remainder is nonzero.
fn poly_div_exact(num: &[u64], den: &[u64]) -> Option<Vec<u64>> {
    let d = den.len() - 1;
    let mut rem: Vec<u64> = num.to_vec();
    if rem.len() <= d {
        return rem.iter().all(|&c| c == 0).then(|| vec![0]);
    }
    let qlen = rem.len() - d;
    let mut quot = vec![0u64; qlen];
    for i in (0..qlen).rev() {
        let c = rem[i + d];
        if c == 0 {
            continue;
        }
        quot[i] = c;
        for (j, &dc) in den.iter().enumerate() {
            rem[i + j] = ff::sub(rem[i + j], ff::mul(c, dc));
        }
    }
    rem.iter().all(|&c| c == 0).then_some(quot)
}

/// Choose `n` distinct nonzero evaluation points starting at `1 + offset`.
/// The protocol only needs distinctness; small consecutive αs keep `αᵉ`
/// computations cheap, and the offset lets callers re-draw when a sparse
/// generalized Vandermonde comes out singular.
///
/// Fails with [`CmpcError::InvalidParams`] when the field cannot supply
/// `n + offset` distinct nonzero points (α-space exhaustion).
pub fn try_evaluation_points(n: usize, offset: u64) -> Result<Vec<u64>> {
    if (n as u64).saturating_add(offset) >= P - 1 {
        return Err(CmpcError::InvalidParams(format!(
            "α space exhausted: need n+offset < p-1 = {} distinct nonzero \
             evaluation points (n={n}, offset={offset})",
            P - 1
        )));
    }
    Ok((1 + offset..=n as u64 + offset).collect())
}

/// Infallible wrapper over [`try_evaluation_points`] for sweep-sized `n`.
///
/// # Panics
/// Panics when the α space is exhausted.
pub fn evaluation_points(n: usize, offset: u64) -> Vec<u64> {
    match try_evaluation_points(n, offset) {
        Ok(pts) => pts,
        Err(e) => panic!("{e}"),
    }
}

/// Pick evaluation points and the generalized-Vandermonde inverse for the
/// given support, re-drawing αs until the matrix inverts. Returns
/// `(alphas, inverse_rows)`.
///
/// Fails with [`CmpcError::InvalidParams`] if `n ≠ |support|` or the α space
/// is exhausted, and with [`CmpcError::NotDecodable`] if no offset in the
/// re-draw budget yields an invertible generalized Vandermonde.
pub fn choose_alphas(n: usize, support: &[u64]) -> Result<(Vec<u64>, Vec<Vec<u64>>)> {
    if n != support.len() {
        return Err(CmpcError::InvalidParams(format!(
            "need exactly |support| = {} evaluation points, got n = {n}",
            support.len()
        )));
    }
    for offset in 0..1024u64 {
        // Exhaustion only gets worse as the offset grows — fail fast.
        let alphas = try_evaluation_points(n, offset)?;
        if let Some(rows) = try_vandermonde_inverse_rows(&alphas, support) {
            return Ok((alphas, rows));
        }
    }
    Err(CmpcError::NotDecodable(format!(
        "no invertible α assignment found in 1024 draws (support len {n})"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;

    fn eval_dense(coeffs: &[u64], x: u64) -> u64 {
        let mut acc = 0u64;
        for &c in coeffs.iter().rev() {
            acc = ff::add(ff::mul(acc, x), c);
        }
        acc
    }

    #[test]
    fn interpolation_roundtrip() {
        property("lagrange roundtrip", 200, |rng| {
            let k = rng.gen_index(12) + 1;
            let coeffs: Vec<u64> = (0..k).map(|_| rng.field_element()).collect();
            // distinct points
            let mut xs: Vec<u64> = (1..=k as u64).collect();
            rng.shuffle(&mut xs);
            let pts: Vec<(u64, u64)> = xs.iter().map(|&x| (x, eval_dense(&coeffs, x))).collect();
            let got = lagrange_interpolate(&pts);
            if got != coeffs {
                return Err(format!("k={k}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "repeated evaluation point")]
    fn repeated_points_rejected() {
        lagrange_interpolate(&[(1, 1), (1, 2)]);
    }

    #[test]
    fn vandermonde_rows_reconstruct_sparse_coeffs() {
        property("generalized vandermonde", 100, |rng| {
            let n = rng.gen_index(10) + 1;
            let mut support: Vec<u64> = Vec::new();
            let mut next = 0u64;
            for _ in 0..n {
                next += rng.gen_range(5) + 1;
                support.push(next);
            }
            let alphas: Vec<u64> = (1..=n as u64).collect();
            let coeffs: Vec<u64> = (0..n).map(|_| rng.field_element()).collect();
            let evals: Vec<u64> = alphas
                .iter()
                .map(|&a| {
                    support
                        .iter()
                        .zip(&coeffs)
                        .fold(0u64, |acc, (&e, &c)| ff::add(acc, ff::mul(c, ff::pow(a, e))))
                })
                .collect();
            let rows = vandermonde_inverse_rows(&alphas, &support);
            for (j, &cj) in coeffs.iter().enumerate() {
                let got = rows[j]
                    .iter()
                    .zip(&evals)
                    .fold(0u64, |acc, (&r, &h)| ff::add(acc, ff::mul(r, h)));
                if got != cj {
                    return Err(format!("coeff {j}: {got} != {cj}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn corrupt_evaluations_are_located_exactly() {
        property("error locator finds planted corruptions", 150, |rng| {
            let k_dim = rng.gen_index(6) + 2; // degree < k_dim
            let a = rng.gen_index(3); // tolerance 0..=2
            let n = k_dim + 2 * a;
            let coeffs: Vec<u64> = (0..k_dim).map(|_| rng.field_element()).collect();
            let mut pts: Vec<(u64, u64)> = (1..=n as u64)
                .map(|x| (x, eval_dense(&coeffs, x)))
                .collect();
            // plant e ≤ a corruptions at distinct positions
            let e = rng.gen_index(a + 1);
            let mut victims: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut victims);
            let mut victims: Vec<usize> = victims.into_iter().take(e).collect();
            victims.sort_unstable();
            for &v in &victims {
                pts[v].1 = ff::add(pts[v].1, 1);
            }
            let got = locate_corrupt_evaluations(&pts, k_dim, a)
                .ok_or_else(|| format!("k={k_dim} a={a} e={e}: not located"))?;
            if got != victims {
                return Err(format!("k={k_dim} a={a}: blamed {got:?}, planted {victims:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn too_many_corruptions_are_refused_not_misdecoded() {
        property("a+1 corruptions never decode", 100, |rng| {
            let k_dim = rng.gen_index(5) + 2;
            let a = rng.gen_index(2) + 1; // 1..=2
            let n = k_dim + 2 * a;
            let coeffs: Vec<u64> = (0..k_dim).map(|_| rng.field_element()).collect();
            let mut pts: Vec<(u64, u64)> = (1..=n as u64)
                .map(|x| (x, eval_dense(&coeffs, x)))
                .collect();
            let mut victims: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut victims);
            for &v in victims.iter().take(a + 1) {
                pts[v].1 = ff::add(pts[v].1, 1 + rng.gen_range(100));
            }
            // With a+1 planted errors the locator must either refuse (None)
            // — the typical case — or, in rare aligned draws, return a
            // candidate; it must never silently blame fewer than a+1 points
            // while claiming consistency with the planted polynomial.
            if let Some(blamed) = locate_corrupt_evaluations(&pts, k_dim, a) {
                // consistency check: excluded + interpolated must actually
                // fit all kept points (the locator's own invariant).
                let kept: Vec<(u64, u64)> = pts
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !blamed.contains(i))
                    .map(|(_, &p)| p)
                    .collect();
                let cand = lagrange_interpolate(&kept[..k_dim]);
                for &(x, y) in &kept[k_dim..] {
                    if eval_dense(&cand, x) != y {
                        return Err("locator returned an inconsistent candidate".into());
                    }
                }
            }
            Ok(())
        });
    }

    /// The locator is Berlekamp–Welch (one O(n³) elimination), not a
    /// subset search: n = 60 with a = 10 would be C(60,10) ≈ 7.5·10¹⁰
    /// candidate exclusions by brute force, yet must locate instantly.
    #[test]
    fn locator_is_polynomial_time_at_fleet_scale() {
        let k_dim = 40usize;
        let a = 10usize;
        let n = k_dim + 2 * a;
        let mut rng = crate::util::rng::ChaChaRng::seed_from_u64(77);
        let coeffs: Vec<u64> = (0..k_dim).map(|_| rng.field_element()).collect();
        let mut pts: Vec<(u64, u64)> = (1..=n as u64)
            .map(|x| (x, eval_dense(&coeffs, x)))
            .collect();
        let mut victims: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut victims);
        let mut victims: Vec<usize> = victims.into_iter().take(a).collect();
        victims.sort_unstable();
        for &v in &victims {
            pts[v].1 = ff::add(pts[v].1, 1 + rng.gen_range(1000));
        }
        let blamed = locate_corrupt_evaluations(&pts, k_dim, a).expect("locatable");
        assert_eq!(blamed, victims);
    }

    /// With fewer surplus points than `2a`, the effective radius shrinks
    /// instead of returning an ambiguous (possibly wrong) exclusion: one
    /// corruption with a single surplus point cannot be attributed.
    #[test]
    fn insufficient_surplus_refuses_instead_of_guessing() {
        let k_dim = 4usize;
        let coeffs = [3u64, 1, 4, 1];
        let mut pts: Vec<(u64, u64)> = (1..=(k_dim as u64 + 1))
            .map(|x| (x, eval_dense(&coeffs, x)))
            .collect();
        pts[2].1 = ff::add(pts[2].1, 9);
        // n = k+1 < k+2: radius 0, the corruption is detected, not placed.
        assert_eq!(locate_corrupt_evaluations(&pts, k_dim, 1), None);
    }

    #[test]
    fn evaluation_points_distinct_nonzero() {
        let pts = evaluation_points(100, 0);
        assert_eq!(pts.len(), 100);
        let mut sorted = pts.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
        assert!(pts.iter().all(|&p| p != 0));
    }
}
