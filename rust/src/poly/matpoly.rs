//! Sparse polynomials with matrix coefficients — the share-generating
//! polynomials `F_A(x) = C_A(x) + S_A(x)` of Phase 1.
//!
//! A `MatPoly` maps exponents to `FpMat` coefficients. Evaluation at a share
//! point `αₙ` walks the support once, maintaining an incremental power of
//! `αₙ` (supports are sorted, so each term costs one field multiplication for
//! the exponent gap plus one matrix axpy).

use std::collections::BTreeMap;

use crate::ff;
use crate::matrix::FpMat;
use crate::runtime::pool::Scratch;

/// Sparse matrix-coefficient polynomial over `GF(p)`.
#[derive(Clone, Debug)]
pub struct MatPoly {
    /// Row count of every coefficient block.
    pub rows: usize,
    /// Column count of every coefficient block.
    pub cols: usize,
    terms: BTreeMap<u64, FpMat>,
}

impl MatPoly {
    /// Empty polynomial whose coefficients will be `rows × cols` blocks.
    pub fn new(rows: usize, cols: usize) -> MatPoly {
        MatPoly {
            rows,
            cols,
            terms: BTreeMap::new(),
        }
    }

    /// Insert a coefficient; panics on duplicate exponent or shape mismatch
    /// (the constructions guarantee one block per power — a duplicate means a
    /// construction bug, and silently adding would mask it).
    pub fn insert(&mut self, power: u64, coeff: FpMat) {
        assert_eq!(
            (coeff.rows, coeff.cols),
            (self.rows, self.cols),
            "coefficient shape mismatch at power {power}"
        );
        let prev = self.terms.insert(power, coeff);
        assert!(prev.is_none(), "duplicate coefficient at power {power}");
    }

    /// The coefficient block at `power`, if that exponent is in the support.
    pub fn coeff(&self, power: u64) -> Option<&FpMat> {
        self.terms.get(&power)
    }

    /// Sorted support `P(F)`.
    pub fn support(&self) -> Vec<u64> {
        self.terms.keys().copied().collect()
    }

    /// Support size `|P(F)|` — the number of nonzero coefficient blocks.
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// Largest exponent in the support (0 for the empty polynomial).
    pub fn degree(&self) -> u64 {
        self.terms.keys().next_back().copied().unwrap_or(0)
    }

    /// Evaluate at `x = alpha`: `Σ coeffₑ · αᵉ`.
    ///
    /// Convenience wrapper over [`MatPoly::eval_into`] with throwaway
    /// buffers; the serving hot path calls `eval_into` with per-worker
    /// [`Scratch`] instead.
    pub fn eval(&self, alpha: u64) -> FpMat {
        let mut out = FpMat::zeros(self.rows, self.cols);
        let mut scratch = Scratch::default();
        self.eval_into(alpha, &mut out, &mut scratch);
        out
    }

    /// Fill `table` with `αᵉ` for every `e` in the sorted support.
    ///
    /// Powers are built Horner-style over the exponent gaps
    /// (`α^{e_{i+1}} = α^{e_i} · α^{e_{i+1}−e_i}`), so the only
    /// exponentiations are one square-and-multiply per *gap* — nothing in
    /// the per-element accumulation loop ever calls [`ff::pow`].
    pub fn power_table(&self, alpha: u64, table: &mut Vec<u64>) {
        table.clear();
        let mut cur_pow = 0u64; // exponent tracked so far
        let mut cur_val = 1u64; // alpha^cur_pow
        for &e in self.terms.keys() {
            cur_val = ff::mul(cur_val, ff::pow(alpha, e - cur_pow));
            cur_pow = e;
            table.push(cur_val);
        }
    }

    /// [`MatPoly::eval`] into caller-owned buffers — the Phase-1 share
    /// encoding kernel (§Perf P4 + P5).
    ///
    /// One pass: the per-worker power table (`scratch.powers`) is
    /// precomputed by [`MatPoly::power_table`], then every coefficient
    /// block is folded into the unreduced accumulator (`scratch.acc`)
    /// with delayed reduction — a single reduction per output element.
    /// After the first call at a given shape, repeat evaluations allocate
    /// nothing (the `alloc_discipline` suite pins this).
    pub fn eval_into(&self, alpha: u64, out: &mut FpMat, scratch: &mut Scratch) {
        self.power_table(alpha, &mut scratch.powers);
        // Disjoint field borrows: powers read-only, acc accumulates.
        let (powers, acc) = (&scratch.powers, &mut scratch.acc);
        self.eval_with_table(powers, out, acc);
    }

    /// [`MatPoly::eval_into`] with a **precomputed** power table — the
    /// fused-batch encoding kernel. When k same-shape jobs are encoded
    /// for one worker, `αₙ` and the support are shared across all k
    /// polynomials, so the table (one [`ff::pow`] chain) is built once
    /// and reused; only the accumulation differs per job. `table[i]`
    /// must be `αᵉ` for the i-th exponent of the sorted support, exactly
    /// as produced by [`MatPoly::power_table`] on any same-support poly.
    pub fn eval_with_table(&self, table: &[u64], out: &mut FpMat, acc: &mut Vec<u64>) {
        assert!(
            self.terms.len() < (1 << 29),
            "too many terms for delayed reduction"
        );
        assert_eq!(table.len(), self.terms.len(), "power table/support mismatch");
        out.rows = self.rows;
        out.cols = self.cols;
        let n = self.rows * self.cols;
        out.data.resize(n, 0);
        acc.clear();
        acc.resize(n, 0);
        for (coeff, &c) in self.terms.values().zip(table.iter()) {
            debug_assert_eq!(coeff.data.len(), n);
            if c == 0 {
                continue;
            }
            for (a, &x) in acc.iter_mut().zip(coeff.data.iter()) {
                *a += c * x as u64;
            }
        }
        // Montgomery fold (REDC fast path up to 65536 terms; the sparse
        // supports here are tiny — t·s + secret terms).
        ff::mont::fold(&mut out.data, acc, self.terms.len());
    }

    /// Polynomial product (used only by tests/small analyses — the protocol
    /// never multiplies matrix polynomials directly; workers multiply
    /// *evaluations*).
    pub fn mul_poly(&self, other: &MatPoly) -> MatPoly {
        assert_eq!(self.cols, other.rows);
        let mut out = MatPoly::new(self.rows, other.cols);
        for (&ea, ca) in &self.terms {
            for (&eb, cb) in &other.terms {
                let prod = ca.matmul(cb);
                let e = ea + eb;
                match out.terms.get_mut(&e) {
                    Some(acc) => *acc = acc.add(&prod),
                    None => {
                        out.terms.insert(e, prod);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff::P;
    use crate::util::rng::ChaChaRng;
    use crate::util::testing::property;

    #[test]
    fn eval_matches_naive() {
        property("matpoly eval == naive", 100, |rng| {
            let rows = rng.gen_index(4) + 1;
            let cols = rng.gen_index(4) + 1;
            let mut poly = MatPoly::new(rows, cols);
            let nterms = rng.gen_index(8) + 1;
            let mut powers: Vec<u64> = (0..nterms).map(|_| rng.gen_range(50)).collect();
            powers.sort_unstable();
            powers.dedup();
            for &e in &powers {
                poly.insert(e, FpMat::random(rng, rows, cols));
            }
            let alpha = rng.gen_range(P - 1) + 1;
            let fast = poly.eval(alpha);
            // naive
            let mut naive = FpMat::zeros(rows, cols);
            for &e in &powers {
                naive.axpy_inplace(ff::pow(alpha, e), poly.coeff(e).unwrap());
            }
            if fast != naive {
                return Err(format!("powers={powers:?} alpha={alpha}"));
            }
            Ok(())
        });
    }

    #[test]
    fn eval_at_zero_is_constant_term() {
        let mut rng = ChaChaRng::seed_from_u64(4);
        let mut poly = MatPoly::new(2, 2);
        let c0 = FpMat::random(&mut rng, 2, 2);
        poly.insert(0, c0.clone());
        poly.insert(3, FpMat::random(&mut rng, 2, 2));
        assert_eq!(poly.eval(0), c0);
    }

    #[test]
    fn product_evaluation_homomorphism() {
        // (F · G)(α) == F(α) · G(α) — the identity Phase 2 relies on.
        property("product evaluation homomorphism", 50, |rng| {
            let (r, k, c) = (2usize, 3usize, 2usize);
            let mut f = MatPoly::new(r, k);
            let mut g = MatPoly::new(k, c);
            for e in 0..3u64 {
                f.insert(e * 2, FpMat::random(rng, r, k));
                g.insert(e * 3, FpMat::random(rng, k, c));
            }
            let alpha = rng.gen_range(P - 1) + 1;
            if f.mul_poly(&g).eval(alpha) != f.eval(alpha).matmul(&g.eval(alpha)) {
                return Err(format!("alpha={alpha}"));
            }
            Ok(())
        });
    }

    #[test]
    fn eval_into_reuses_scratch_across_alphas_and_shapes() {
        let mut rng = ChaChaRng::seed_from_u64(21);
        let mut scratch = Scratch::default();
        let mut out = FpMat::zeros(0, 0);
        for _ in 0..12 {
            let rows = rng.gen_index(4) + 1;
            let cols = rng.gen_index(4) + 1;
            let mut poly = MatPoly::new(rows, cols);
            let mut powers: Vec<u64> = (0..rng.gen_index(6) + 1)
                .map(|_| rng.gen_range(80))
                .collect();
            powers.sort_unstable();
            powers.dedup();
            for &e in &powers {
                poly.insert(e, FpMat::random(&mut rng, rows, cols));
            }
            let alpha = rng.gen_range(P);
            poly.eval_into(alpha, &mut out, &mut scratch);
            assert_eq!(out, poly.eval(alpha), "alpha={alpha}");
        }
    }

    #[test]
    fn power_table_matches_pow() {
        let mut rng = ChaChaRng::seed_from_u64(22);
        let mut poly = MatPoly::new(1, 1);
        for e in [0u64, 3, 4, 17, 40] {
            poly.insert(e, FpMat::random(&mut rng, 1, 1));
        }
        let mut table = Vec::new();
        for alpha in [0u64, 1, 2, 65536] {
            poly.power_table(alpha, &mut table);
            let expect: Vec<u64> = poly.support().iter().map(|&e| ff::pow(alpha, e)).collect();
            assert_eq!(table, expect, "alpha={alpha}");
        }
    }

    /// A power table built once must be reusable across distinct
    /// same-support polynomials — the fused-batch sharing contract.
    #[test]
    fn eval_with_shared_table_matches_eval_into() {
        let mut rng = ChaChaRng::seed_from_u64(23);
        let powers = [0u64, 2, 5, 9, 31];
        let polys: Vec<MatPoly> = (0..4)
            .map(|_| {
                let mut p = MatPoly::new(3, 2);
                for &e in &powers {
                    p.insert(e, FpMat::random(&mut rng, 3, 2));
                }
                p
            })
            .collect();
        let mut table = Vec::new();
        let mut acc = Vec::new();
        let mut scratch = Scratch::default();
        let mut via_table = FpMat::zeros(0, 0);
        let mut via_eval = FpMat::zeros(0, 0);
        for alpha in [0u64, 1, 7, 65536] {
            polys[0].power_table(alpha, &mut table);
            for poly in &polys {
                poly.eval_with_table(&table, &mut via_table, &mut acc);
                poly.eval_into(alpha, &mut via_eval, &mut scratch);
                assert_eq!(via_table, via_eval, "alpha={alpha}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "duplicate coefficient")]
    fn duplicate_power_rejected() {
        let mut poly = MatPoly::new(1, 1);
        poly.insert(2, FpMat::zeros(1, 1));
        poly.insert(2, FpMat::zeros(1, 1));
    }
}
