//! Bench E2 — Fig. 3 regeneration: required workers vs partition ratio s/t
//! (st=36, z=42).

use cmpc::analysis::figures::fig3_workers;
use cmpc::benchkit::bench;

fn main() {
    let mut rows = Vec::new();
    bench("fig3/enumerate st=36 z=42", 1, 10, || {
        rows = fig3_workers(36, 42);
    });
    println!("\n(s,t)      AGE  PolyDot  Entangled  SSMM  GCSA-NA");
    for r in &rows {
        println!(
            "({:>2},{:>2})  {:>5}  {:>7}  {:>9}  {:>4}  {:>7}",
            r.s, r.t, r.age, r.polydot, r.entangled, r.ssmm, r.gcsa_na
        );
    }
    // Paper claims at z=42, st=36: PolyDot < all baselines exactly for
    // (2,18), (3,12), (4,9).
    let winners: Vec<(usize, usize)> = rows
        .iter()
        .filter(|r| {
            r.polydot < r.entangled && r.polydot < r.ssmm && r.polydot < r.gcsa_na
        })
        .map(|r| (r.s, r.t))
        .collect();
    println!("\nPolyDot beats all baselines at: {winners:?}");
    assert_eq!(winners, vec![(2, 18), (3, 12), (4, 9)]);
}
