//! Bench E1 — Fig. 2 regeneration: required workers vs number of colluding
//! workers (s=4, t=15, z=1..300) for all five schemes.
//!
//! Times the exact-enumeration pipeline (the expensive part is AGE's λ* scan
//! at every z) and prints the regime summary the paper reports.

use cmpc::analysis::figures::fig2_workers;
use cmpc::benchkit::bench;

fn main() {
    // Time a reduced and the full paper range.
    bench("fig2/enumerate s=4 t=15 z<=60", 1, 5, || {
        let rows = fig2_workers(4, 15, 60);
        assert_eq!(rows.len(), 60);
    });
    let mut rows = Vec::new();
    bench("fig2/enumerate s=4 t=15 z<=300 (paper range)", 0, 1, || {
        rows = fig2_workers(4, 15, 300);
    });

    // Regime table (paper: SSMM best-of-rest ≲48, PolyDot 49..≈180,
    // Entangled/GCSA ≳181; AGE minimal throughout).
    let mut boundaries = Vec::new();
    let mut prev = "";
    for r in &rows {
        let cands = [
            ("PolyDot", r.polydot),
            ("Entangled", r.entangled),
            ("SSMM", r.ssmm),
            ("GCSA-NA", r.gcsa_na),
        ];
        let best = cands.iter().min_by_key(|&&(_, v)| v).unwrap().0;
        if best != prev {
            boundaries.push((r.z, best));
            prev = best;
        }
        assert!(r.age <= cands.iter().map(|&(_, v)| v).min().unwrap());
    }
    println!("fig2 second-best regime boundaries: {boundaries:?}");
    println!(
        "fig2 anchors: z=1 AGE={} | z=150 AGE={} | z=300 AGE={}",
        rows[0].age, rows[149].age, rows[299].age
    );
}
