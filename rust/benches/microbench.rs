//! Microbenchmarks of the L3 hot paths feeding the §Perf optimization loop:
//! field reduction, the native modular matmul (per-worker Phase-2 kernel),
//! share-polynomial evaluation, the generalized-Vandermonde setup solve,
//! and the sumset enumeration kernel behind the figures.

use cmpc::benchkit::bench;
use cmpc::codes::{AgeCmpc, CmpcScheme};
use cmpc::ff;
use cmpc::matrix::FpMat;
use cmpc::mpc::source;
use cmpc::poly::interp::choose_alphas;
use cmpc::poly::powers::sumset_size;
use cmpc::util::rng::ChaChaRng;

fn main() {
    let mut rng = ChaChaRng::seed_from_u64(3);

    // --- field reduction throughput ---
    let xs: Vec<u64> = (0..1 << 16).map(|_| rng.next_u64()).collect();
    let mut sink = 0u64;
    let meas = bench("ff/reduce 65536 values", 3, 30, || {
        let mut acc = 0u64;
        for &x in &xs {
            acc ^= ff::reduce(x);
        }
        sink ^= acc;
    });
    let ns_per = meas.median.as_nanos() as f64 / xs.len() as f64;
    println!("  -> {ns_per:.2} ns/reduce (sink {sink})");

    // --- native modular matmul (the worker hot spot) ---
    for size in [64usize, 128, 256] {
        let a = FpMat::random(&mut rng, size, size);
        let b = FpMat::random(&mut rng, size, size);
        let meas = bench(&format!("matmul/native {size}x{size}x{size}"), 2, 10, || {
            std::hint::black_box(a.matmul(&b));
        });
        let mults = (size * size * size) as f64;
        println!(
            "  -> {:.1} M field-mults/s",
            mults / meas.median.as_secs_f64() / 1e6
        );
    }

    // --- share polynomial evaluation (Phase 1) ---
    let scheme = AgeCmpc::with_optimal_lambda(4, 2, 3);
    let m = 256;
    let a = FpMat::random(&mut rng, m, m);
    let fa = source::build_f_a(&scheme, &a, &mut rng);
    bench("phase1/eval F_A(α) m=256 s=4 t=2", 2, 10, || {
        std::hint::black_box(fa.eval(12345));
    });

    // --- generalized Vandermonde setup solve (coordinator, cached) ---
    for (s, t, z) in [(2usize, 2usize, 2usize), (4, 2, 3), (3, 3, 4)] {
        let sch = AgeCmpc::with_optimal_lambda(s, t, z);
        let support = sch.reconstruction_support();
        let n = sch.n_workers();
        bench(
            &format!("setup/vandermonde N={n} (s={s},t={t},z={z})"),
            1,
            10,
            || {
                std::hint::black_box(choose_alphas(n, &support).unwrap());
            },
        );
    }

    // --- sumset enumeration kernel (figures / λ* scan) ---
    let sch = AgeCmpc::new(4, 15, 150, 75);
    let (pa, pb) = (sch.support_a(), sch.support_b());
    bench("enum/sumset s=4 t=15 z=150", 3, 30, || {
        std::hint::black_box(sumset_size(&pa, &pb));
    });
    bench("enum/AGE λ* scan s=4 t=15 z=60", 1, 5, || {
        std::hint::black_box(AgeCmpc::with_optimal_lambda(4, 15, 60).n_workers());
    });
}
