//! Bench PR2–PR8 — the serving core's perf trajectory.
//!
//! Runs the Fig. 2 anchor shapes (Example-1 parameters, serving-sized
//! matrices) through a provisioned `Deployment` at 1/2/4/8 pool threads,
//! recording per-phase latency (encode / worker compute / reconstruct),
//! end-to-end job latency (verify on — the full serving path including the
//! parallel reference product), drain throughput on a shared coordinator,
//! and peak RSS. PR 3 adds a **job-churn** scenario: small-m jobs/sec on a
//! provision-once persistent runtime vs. provisioning (spawning N worker
//! threads + solving setup) per job — the cost the persistent runtime
//! amortizes away. PR 4 adds a **fault** scenario: e2e latency with
//! 0/1/2 injected stragglers, full-quota wait vs the early-decode fast
//! path — since PR 5 the stragglers sit behind shaped slow *links*
//! (in-flight latency on their inbound G-shares), so the fast path's
//! abort-ack drain stays off the straggler's clock and the win is real.
//! PR 5 adds a **wire** scenario: each scheme's job runs once through the
//! loopback TCP cluster (real sockets, framed codec) and the measured
//! worker↔worker bytes are reported against the analytical ζ — framing
//! overhead must stay under 5%. PR 6 adds a **gateway** scenario: the
//! multi-tenant load driver pushes concurrent closed-loop tenants through
//! a loopback serving gateway (admission → batcher → shared deployment)
//! and reports sustained QPS, gateway-observed p50/p99 latency, and the
//! batching profile straight from `GatewayStats`. PR 7 adds a
//! **byzantine** scenario: clean-run e2e at adversary tolerance a=0/1/2 —
//! the raised `t²+z+2a` recovery quota plus the fingerprint error-locator
//! pass — reported as overhead against the a=0 baseline. PR 8 adds a
//! **fused** scenario — k same-shape jobs through one wide
//! `Deployment::execute_fused_seeded` pass vs the same k jobs run
//! sequentially with identical seeds (batch 1/4/16 per scheme, output
//! identity asserted on every pair) — and a **gate** case: one fixed
//! m=32 single-thread job normalized by an in-process scalar calibration
//! loop, yielding the machine-portable `e2e_per_calib` ratio the CI
//! smoke lane compares against the committed baseline (>10% regression
//! fails the lane). PR 9 adds a **pipeline** scenario: chained secure
//! matrix ops (`Deployment::execute_pipeline_seeded`) measured
//! stages-vs-e2e — per-round wall time from `PipelineOutput::stage_elapsed`
//! summed against the end-to-end clock, so the driver overhead between
//! rounds (boundary ops + re-share bookkeeping) is visible — plus the
//! naive alternative (decode every stage at the master and re-encode)
//! for the amortization ratio. PR 10 adds an **autoscale** scenario:
//! adaptive-vs-static over two deterministic mis-provisioning profiles —
//! a *bandwidth* profile (deployment pinned at λ = 0 pays ~11% extra
//! Phase-2 traffic; the controller reads live telemetry and swaps to
//! λ* = 2) and a *straggler* profile (seeded mid-exchange worker kills
//! erode the λ = 2 margin; the controller drafts standby capacity back
//! to λ = 0). Every static `(scheme, λ)` point on the curve runs the same
//! job stream, and the adaptive run must converge onto the best static
//! config with zero dropped jobs — asserted, not just reported. Results
//! are printed in the in-tree bench format *and* emitted as
//! machine-readable `BENCH_10.json` so later PRs can diff the trajectory.
//!
//! Usage (from `rust/`):
//!
//! ```sh
//! cargo bench --bench perf_core                      # full run → ../BENCH_10.json
//! cargo bench --bench perf_core -- --smoke --out /tmp/b.json   # CI schema smoke
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpc::analysis;
use cmpc::autoscale::{AutoscaleConfig, Autoscaler, Decision};
use cmpc::benchkit::{peak_rss_bytes, per_second, Json};
use cmpc::codes::SchemeParams;
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::gateway::client::{run_load, LoadPlan};
use cmpc::gateway::{Gateway, GatewayConfig, LocalEngine};
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, PayloadClass};
use cmpc::mpc::pipeline::{pipeline_input, pipeline_weight, Pipeline};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::manifest::TopologyManifest;
use cmpc::transport::node::run_local_cluster;
use cmpc::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

struct Case {
    scheme: String,
    s: usize,
    t: usize,
    z: usize,
    m: usize,
    threads: usize,
    iters: usize,
    encode_ns: u64,
    compute_ns: u64,
    decode_ns: u64,
    e2e_ns: u64,
    jobs_per_sec: f64,
    speedup_e2e_vs_1t: f64,
    /// Process RSS high-water mark sampled when this case finished
    /// (monotonic across the run — per-case deltas, not absolutes).
    peak_rss_bytes: u64,
}

fn ns(d: Duration) -> u64 {
    d.as_nanos() as u64
}

struct ChurnCase {
    m: usize,
    jobs: usize,
    /// Jobs/sec streamed through one persistent runtime (provision once).
    warm_jobs_per_sec: f64,
    /// Jobs/sec when every job provisions its own deployment (N thread
    /// spawns + the O(N³) setup solve per job — the pre-runtime shape).
    cold_jobs_per_sec: f64,
    speedup_warm_vs_cold: f64,
}

/// Job churn at small m: provision-once vs per-job provisioning.
fn run_churn(s: usize, t: usize, z: usize, m: usize, jobs: usize) -> ChurnCase {
    let params = SchemeParams::new(s, t, z);
    let mut rng = ChaChaRng::seed_from_u64(0xC4);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let config = ProtocolConfig::builder().verify(false).build();
    let provision = || {
        Deployment::provision(SchemeSpec::Age { lambda: None }, params, config.clone())
            .expect("provision")
    };
    // Warm: one runtime, `jobs` streamed jobs (plus one unmeasured warmup
    // that grows the fabric buffer pool to steady state).
    let dep = provision();
    dep.execute_seeded(&a, &b, 1).expect("warmup");
    let t0 = Instant::now();
    for i in 0..jobs {
        dep.execute_seeded(&a, &b, 2 + i as u64).expect("warm job");
    }
    let warm_jobs_per_sec = per_second(jobs as u64, t0.elapsed());
    // Cold: provision (thread spawns + setup solve) inside every job.
    let t0 = Instant::now();
    for i in 0..jobs {
        let dep = provision();
        dep.execute_seeded(&a, &b, 2 + i as u64).expect("cold job");
    }
    let cold_jobs_per_sec = per_second(jobs as u64, t0.elapsed());
    let speedup = warm_jobs_per_sec / cold_jobs_per_sec.max(1e-9);
    println!(
        "bench perf_core/churn m={m} jobs={jobs}            warm={warm_jobs_per_sec:.1} jobs/s \
         cold={cold_jobs_per_sec:.1} jobs/s speedup={speedup:.2}"
    );
    ChurnCase {
        m,
        jobs,
        warm_jobs_per_sec,
        cold_jobs_per_sec,
        speedup_warm_vs_cold: speedup,
    }
}

struct FaultCase {
    stragglers: usize,
    delay_ms: u64,
    /// Best-of-iters e2e with the default full-quota (tail-drain) wait.
    e2e_full_ns: u64,
    /// Best-of-iters e2e with `early_decode`: reconstruct at the `t²+z`
    /// quota, abort the straggler tail.
    e2e_early_ns: u64,
    /// `e2e_full_ns / e2e_early_ns` — the measured straggler-tolerance win.
    early_decode_win: f64,
}

/// Straggler resilience: `stragglers` workers sit behind slow links —
/// every inbound G-share into them is shaped `+delay` *in flight* (their
/// own compute and outbound shares are on time, so every other worker
/// finishes promptly). The full-quota path waits for the victims' late
/// I-shares; the early-decode path aborts them while they idle-wait, so
/// they ack instantly and the job returns with exact counters.
fn run_fault(
    s: usize,
    t: usize,
    z: usize,
    m: usize,
    stragglers: usize,
    delay: Duration,
    iters: usize,
) -> FaultCase {
    let params = SchemeParams::new(s, t, z);
    let mut rng = ChaChaRng::seed_from_u64(0xF4);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let run = |early: bool| -> u64 {
        let mut shaper = LinkShaper::new();
        for victim in 0..stragglers {
            shaper = shaper.rule(
                ShapeRule::new(LinkSpec::latency(delay))
                    .to_node(victim)
                    .class(PayloadClass::GShare),
            );
        }
        let mut config = ProtocolConfig::builder()
            .verify(false)
            .early_decode(early);
        if stragglers > 0 {
            config = config.shaper(shaper.into_shared());
        }
        let dep = Deployment::provision(SchemeSpec::Age { lambda: None }, params, config.build())
            .expect("provision");
        let mut best = u64::MAX;
        for i in 0..iters {
            let t0 = Instant::now();
            dep.execute_seeded(&a, &b, 40 + i as u64).expect("fault job");
            best = best.min(ns(t0.elapsed()));
        }
        best
    };
    let e2e_full_ns = run(false);
    let e2e_early_ns = run(true);
    let win = e2e_full_ns as f64 / e2e_early_ns.max(1) as f64;
    println!(
        "bench perf_core/fault stragglers={stragglers} delay={delay:?}   \
         full={e2e_full_ns}ns early={e2e_early_ns}ns win={win:.2}"
    );
    FaultCase {
        stragglers,
        delay_ms: delay.as_millis() as u64,
        e2e_full_ns,
        e2e_early_ns,
        early_decode_win: win,
    }
}

struct WireCase {
    scheme: String,
    m: usize,
    n_workers: usize,
    /// Worker↔worker bytes actually written to loopback TCP sockets
    /// (framed codec, summed over every node's transport).
    w2w_wire_bytes: u64,
    /// Analytical ζ (eq. 34) in bytes (scalars × 4).
    zeta_bytes: u64,
    /// `(w2w_wire_bytes − zeta_bytes) / zeta_bytes`, percent — the
    /// framing overhead; must stay under 5%.
    overhead_pct: f64,
    /// Total bytes on the wire, all classes + control.
    total_wire_bytes: u64,
    e2e_ns: u64,
}

/// Serialized bytes/job per scheme vs analytical ζ: one job through the
/// loopback TCP cluster — transmitted, not just counted.
fn run_wire(scheme: &str, s: usize, t: usize, z: usize, m: usize) -> WireCase {
    let mut manifest =
        TopologyManifest::template(scheme, s, t, z, m, 0xB17E, 1, "127.0.0.1", 0)
            .expect("wire manifest");
    manifest.recv_timeout = Duration::from_secs(30);
    let t0 = Instant::now();
    let report = run_local_cluster(&manifest, None).expect("wire cluster");
    let e2e_ns = ns(t0.elapsed());
    assert!(report.master.jobs.iter().all(|j| j.verified));
    let n = manifest.n_workers() as u64;
    let zeta_bytes = analysis::communication_overhead(m, t, n) as u64 * 4;
    let w2w = report.wire.bytes_worker_to_worker;
    assert!(w2w >= zeta_bytes, "wire below ζ: {w2w} < {zeta_bytes}");
    let overhead_pct = (w2w - zeta_bytes) as f64 * 100.0 / zeta_bytes as f64;
    assert!(
        overhead_pct < 5.0,
        "{scheme}: framing overhead {overhead_pct:.2}% breaches the 5% budget"
    );
    println!(
        "bench perf_core/wire scheme={scheme} m={m} N={n}    w2w={w2w}B zeta={zeta_bytes}B \
         overhead={overhead_pct:.2}% total={}B",
        report.wire.total_bytes()
    );
    // Let the cluster's detached reader threads release their sockets
    // before the next scheme's bind wave.
    std::thread::sleep(Duration::from_millis(50));
    WireCase {
        scheme: scheme.to_string(),
        m,
        n_workers: n as usize,
        w2w_wire_bytes: w2w,
        zeta_bytes,
        overhead_pct,
        total_wire_bytes: report.wire.total_bytes(),
        e2e_ns,
    }
}

struct GatewayCase {
    tenants: usize,
    jobs_per_tenant: usize,
    m: usize,
    /// Client-observed completion rate across all tenants.
    sustained_qps: f64,
    /// Gateway-observed (admission → response) latency percentiles.
    p50_us: u64,
    p99_us: u64,
    batches: u64,
    batched_jobs: u64,
    max_batch: usize,
    /// `GatewayStats::batch_size` with trailing zero buckets trimmed
    /// (bucket `i` counts batches of `i + 1` jobs).
    batch_size_hist: Vec<u64>,
}

/// Serving-gateway throughput: `tenants` concurrent closed-loop clients
/// drive the deterministic job sequence through a loopback gateway onto
/// one shared in-process deployment.
fn run_gateway(tenants: usize, jobs_per_tenant: usize, m: usize) -> GatewayCase {
    let engine = Arc::new(LocalEngine::new(
        CoordinatorConfig::builder().verify(false).build(),
    ));
    let gateway = Gateway::start("127.0.0.1:0", GatewayConfig::default(), engine)
        .expect("gateway start");
    let plan = LoadPlan {
        addr: gateway.local_addr().to_string(),
        tenants: (0..tenants as u32).collect(),
        jobs_per_tenant,
        m,
        s: 2,
        t: 2,
        z: 2,
        adv: 0,
        seed: 0x6A7E,
        qps: None,
    };
    let report = run_load(&plan).expect("gateway load");
    assert_eq!(report.accepted(), tenants * jobs_per_tenant, "open admission rejected a job");
    let stats = gateway.shutdown();
    let mut hist = stats.batch_size.to_vec();
    while hist.last() == Some(&0) {
        hist.pop();
    }
    let case = GatewayCase {
        tenants,
        jobs_per_tenant,
        m,
        sustained_qps: report.qps(),
        p50_us: stats.p50_latency_us(),
        p99_us: stats.p99_latency_us(),
        batches: stats.batches,
        batched_jobs: stats.batched_jobs,
        max_batch: stats.max_batch(),
        batch_size_hist: hist,
    };
    println!(
        "bench perf_core/gateway tenants={tenants} jobs={} m={m}  qps={:.1} p50={}us \
         p99={}us batches={} max_batch={}",
        tenants * jobs_per_tenant,
        case.sustained_qps,
        case.p50_us,
        case.p99_us,
        case.batches,
        case.max_batch,
    );
    case
}

struct ByzantineCase {
    adversary_tolerance: usize,
    m: usize,
    /// Best-of-iters clean-run e2e at recovery quota `t²+z+2a`.
    e2e_ns: u64,
    /// Reconstruction window of the best run — includes the per-share
    /// fingerprinting and the error-locator pass when `a > 0`.
    decode_ns: u64,
    /// `e2e_ns / e2e_ns(a=0)` from the same sweep — what the Byzantine
    /// margin costs when nobody actually cheats (1.0 for a=0).
    overhead_vs_a0: f64,
}

/// Byzantine decode overhead: the same job at adversary tolerance `adv`,
/// no corruption injected — measures the price of the raised quota (two
/// extra I-share waits per tolerated adversary) plus the locator pass.
fn run_byzantine(adv: usize, m: usize, iters: usize, baseline_ns: Option<u64>) -> ByzantineCase {
    let params = SchemeParams::new(2, 2, 2);
    let mut rng = ChaChaRng::seed_from_u64(0xB7);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .verify(false)
            .adversary_tolerance(adv)
            .build(),
    )
    .expect("provision");
    dep.execute_seeded(&a, &b, 1).expect("warmup");
    let mut best = u64::MAX;
    let mut decode_ns = 0u64;
    for i in 0..iters {
        let t0 = Instant::now();
        let out = dep
            .execute_seeded(&a, &b, 2 + i as u64)
            .expect("byzantine job");
        let e2e = ns(t0.elapsed());
        assert!(
            out.blamed_workers.is_empty(),
            "clean run blamed a worker at a={adv}"
        );
        if e2e < best {
            best = e2e;
            decode_ns = ns(out.timings.phase3_reconstruct);
        }
    }
    let overhead = best as f64 / baseline_ns.unwrap_or(best).max(1) as f64;
    println!(
        "bench perf_core/byzantine a={adv} m={m}          e2e={best}ns decode={decode_ns}ns \
         overhead_vs_a0={overhead:.2}"
    );
    ByzantineCase {
        adversary_tolerance: adv,
        m,
        e2e_ns: best,
        decode_ns,
        overhead_vs_a0: overhead,
    }
}

struct FusedCase {
    scheme: String,
    m: usize,
    batch: usize,
    /// Best-of-iters wall time for the whole batch through one
    /// `execute_fused_seeded` call (batch 1 routes through the sequential
    /// fallback — the amortization-free reference point).
    fused_ns: u64,
    /// Best-of-iters wall time for the same jobs as k sequential
    /// `execute_seeded` calls with the same seeds.
    sequential_ns: u64,
    speedup_fused_vs_seq: f64,
    fused_jobs_per_sec: f64,
}

/// Fused-batch amortization: k same-shape jobs as one wide pass vs the
/// same k jobs run job-at-a-time, identical per-job seeds. The outputs
/// are asserted identical pair-by-pair before anything is timed — the
/// fused path is a scheduling change, never a numeric one.
fn run_fused(spec: SchemeSpec, label: &str, m: usize, batch: usize, iters: usize) -> FusedCase {
    let params = SchemeParams::new(2, 2, 2);
    let mut rng = ChaChaRng::seed_from_u64(0xF05E + batch as u64);
    let mats: Vec<(FpMat, FpMat)> = (0..batch)
        .map(|_| (FpMat::random(&mut rng, m, m), FpMat::random(&mut rng, m, m)))
        .collect();
    let jobs: Vec<(&FpMat, &FpMat)> = mats.iter().map(|(a, b)| (a, b)).collect();
    let seeds: Vec<u64> = (0..batch as u64).map(|i| 0xF00 + i).collect();
    let dep = Deployment::provision(
        spec,
        params,
        ProtocolConfig::builder().verify(false).build(),
    )
    .expect("provision");
    // Warmup + identity pin: fused output j must equal the sequential run
    // of job j under the same seed.
    let fused_out = dep.execute_fused_seeded(&jobs, &seeds).expect("fused warmup");
    for ((out, &(a, b)), &seed) in fused_out.iter().zip(&jobs).zip(&seeds) {
        let seq = dep.execute_seeded(a, b, seed).expect("sequential warmup");
        assert_eq!(out.y, seq.y, "{label}: fused/sequential divergence");
    }
    let (mut fused_ns, mut seq_ns) = (u64::MAX, u64::MAX);
    for _ in 0..iters {
        let t0 = Instant::now();
        dep.execute_fused_seeded(&jobs, &seeds).expect("fused batch");
        fused_ns = fused_ns.min(ns(t0.elapsed()));
        let t0 = Instant::now();
        for (&(a, b), &seed) in jobs.iter().zip(&seeds) {
            dep.execute_seeded(a, b, seed).expect("sequential job");
        }
        seq_ns = seq_ns.min(ns(t0.elapsed()));
    }
    let speedup = seq_ns as f64 / fused_ns.max(1) as f64;
    let fused_jobs_per_sec = per_second(batch as u64, Duration::from_nanos(fused_ns));
    println!(
        "bench perf_core/fused scheme={label} m={m} batch={batch:<2}  fused={fused_ns}ns \
         seq={seq_ns}ns speedup={speedup:.2} ({fused_jobs_per_sec:.1} jobs/s fused)"
    );
    FusedCase {
        scheme: label.to_string(),
        m,
        batch,
        fused_ns,
        sequential_ns: seq_ns,
        speedup_fused_vs_seq: speedup,
        fused_jobs_per_sec,
    }
}

/// Machine-speed calibration: a fixed scalar `%`-reduction matmul whose
/// code path shares nothing with the crate's Montgomery kernels. The
/// regression gate compares `e2e_ns / calib_ns` — a dimensionless,
/// machine-normalized latency — so the committed baseline transfers
/// across runner generations.
fn calibrate_ns() -> u64 {
    use std::hint::black_box;
    const D: usize = 48;
    let a: Vec<u64> = (0..D * D).map(|i| (i as u64).wrapping_mul(2654435761) % 65537).collect();
    let b: Vec<u64> = (0..D * D).map(|i| (i as u64).wrapping_mul(40503) % 65537).collect();
    let mut c = vec![0u64; D * D];
    let mut best = u64::MAX;
    for _ in 0..5 {
        let t0 = Instant::now();
        for i in 0..D {
            for j in 0..D {
                let mut acc = 0u64;
                for k in 0..D {
                    acc = (acc + black_box(a[i * D + k]) * b[k * D + j]) % 65537;
                }
                c[i * D + j] = acc;
            }
        }
        black_box(&mut c);
        best = best.min(ns(t0.elapsed()));
    }
    best
}

struct GateCase {
    m: usize,
    threads: usize,
    e2e_ns: u64,
    calib_ns: u64,
    /// `e2e_ns / calib_ns` — what the CI smoke lane diffs against the
    /// committed `BENCH_10.json` gate (fails at >10% regression).
    e2e_per_calib: f64,
}

/// The CI regression-gate shape: a fixed (2,2,2) m=32 single-thread job,
/// best-of-iters, normalized by the in-process calibration loop. Runs in
/// both smoke and full mode so the committed full-run baseline and the
/// smoke measurement are the same quantity.
fn run_gate(iters: usize) -> GateCase {
    let calib_ns = calibrate_ns();
    let (m, threads) = (32usize, 1usize);
    let params = SchemeParams::new(2, 2, 2);
    let mut rng = ChaChaRng::seed_from_u64(0x6A7E2);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().verify(false).threads(threads).build(),
    )
    .expect("provision");
    dep.execute_seeded(&a, &b, 1).expect("gate warmup");
    let mut e2e_ns = u64::MAX;
    for i in 0..iters.max(2) {
        let t0 = Instant::now();
        dep.execute_seeded(&a, &b, 2 + i as u64).expect("gate job");
        e2e_ns = e2e_ns.min(ns(t0.elapsed()));
    }
    let ratio = e2e_ns as f64 / calib_ns.max(1) as f64;
    println!(
        "bench perf_core/gate m={m} threads={threads}        e2e={e2e_ns}ns calib={calib_ns}ns \
         e2e_per_calib={ratio:.3}"
    );
    GateCase {
        m,
        threads,
        e2e_ns,
        calib_ns,
        e2e_per_calib: ratio,
    }
}

struct PipelineCase {
    spec: String,
    m: usize,
    rounds: usize,
    /// Per-round wall time of the best e2e run, in round order
    /// (`PipelineOutput::stage_elapsed`).
    stage_ns: Vec<u64>,
    /// Sum of `stage_ns` — the fabric-round portion of the e2e clock.
    stages_sum_ns: u64,
    /// Best-of-iters end-to-end pipeline wall time (one Phase-3 decode).
    e2e_ns: u64,
    /// Best-of-iters wall time of the naive chain: decode **every** stage
    /// at the master and re-encode it as a fresh job's input.
    naive_ns: u64,
    /// `naive_ns / e2e_ns` — what the masked re-share saves.
    speedup_vs_naive: f64,
}

/// Stages-vs-e2e for a chained secure computation, plus the naive
/// decode-re-encode alternative it replaces. Outputs of the two paths are
/// not compared here (truncation boundaries legitimately differ by the
/// probabilistic ±1 ulp) — byte-identity against the masked reference is
/// `tests/pipeline.rs`'s job; this measures the amortization.
fn run_pipeline_bench(spec_str: &str, m: usize, iters: usize) -> PipelineCase {
    let pipe = Pipeline::parse_spec(spec_str).expect("pipeline spec");
    let params = SchemeParams::new(2, 2, 2);
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().verify(false).build(),
    )
    .expect("provision");
    let seed = 0x919E;
    let x = pipeline_input(seed, m);
    let weights: Vec<FpMat> = (0..pipe.rounds())
        .map(|r| pipeline_weight(seed, m, r as u32))
        .collect();
    let wrefs: Vec<&FpMat> = weights.iter().collect();
    dep.execute_pipeline_seeded(&pipe, &x, &wrefs, seed).expect("pipeline warmup");
    let mut e2e_ns = u64::MAX;
    let mut stage_ns: Vec<u64> = Vec::new();
    for i in 0..iters.max(1) {
        let t0 = Instant::now();
        let out = dep
            .execute_pipeline_seeded(&pipe, &x, &wrefs, seed + 1 + i as u64)
            .expect("pipeline job");
        let e2e = ns(t0.elapsed());
        if e2e < e2e_ns {
            e2e_ns = e2e;
            stage_ns = out.stage_elapsed.iter().map(|&d| ns(d)).collect();
        }
    }
    // Naive chain: one full decode per stage, the intermediate re-entering
    // as the next job's plaintext input — the per-stage master round trips
    // (and leaks) the pipeline exists to avoid.
    let mut naive_ns = u64::MAX;
    for i in 0..iters.max(1) {
        let t0 = Instant::now();
        let mut state = x.clone();
        for (r, w) in weights.iter().enumerate() {
            let out = dep
                .execute_seeded(&state, w, seed + 100 + (i * pipe.rounds() + r) as u64)
                .expect("naive stage");
            state = out.y;
        }
        naive_ns = naive_ns.min(ns(t0.elapsed()));
    }
    let stages_sum_ns: u64 = stage_ns.iter().sum();
    let speedup = naive_ns as f64 / e2e_ns.max(1) as f64;
    println!(
        "bench perf_core/pipeline `{spec_str}` m={m}   e2e={e2e_ns}ns stages_sum={stages_sum_ns}ns \
         naive={naive_ns}ns speedup_vs_naive={speedup:.2}"
    );
    PipelineCase {
        spec: spec_str.to_string(),
        m,
        rounds: pipe.rounds(),
        stage_ns,
        stages_sum_ns,
        e2e_ns,
        naive_ns,
        speedup_vs_naive: speedup,
    }
}

struct AutoscaleStaticCase {
    spec: String,
    lambda: u64,
    n_workers: usize,
    jobs: u64,
    dropped_jobs: u64,
    /// Measured Phase-2 worker↔worker scalars per job (`DeploymentTelemetry`).
    w2w_scalars_per_job: u64,
    mean_e2e_ns: u64,
    /// Workers above the `t²+z` recovery quota once the profile's kills
    /// land — the standby headroom a straggler-degraded fleet lives on.
    recovery_margin: i64,
}

struct AutoscaleCase {
    profile: String,
    start_spec: String,
    /// Scheme the controller had converged onto when the stream ended.
    converged_spec: String,
    /// The static sweep's winner under the profile's objective.
    best_static_spec: String,
    reconfigurations: u64,
    jobs: u64,
    dropped_jobs: u64,
    /// `converged_spec == best_static_spec` — the adaptive ≥ every-static
    /// claim, asserted before this struct is built.
    converged_matches_best: bool,
    adaptive_w2w_scalars_per_job: u64,
    adaptive_mean_e2e_ns: u64,
    statics: Vec<AutoscaleStaticCase>,
}

const AUTOSCALE_M: usize = 8;
/// `t² + z` at a = 0 for the Example-1 shape — the recovery quota the
/// standby margin is measured against.
const AUTOSCALE_QUOTA: i64 = 6;

/// Provision one (2,2,2) AGE deployment at `lambda`; `kills > 0` arms the
/// straggler profile (seeded mid-exchange worker kills + early decode).
fn autoscale_provision(lambda: usize, kills: usize) -> Arc<Deployment> {
    let mut config = ProtocolConfig::builder().verify(false).threads(1);
    if kills > 0 {
        let model = analysis::CostModel::new(2, 2, 2);
        let n = model
            .worker_counts()
            .iter()
            .find(|&&(l, _)| l == lambda as u64)
            .map(|&(_, n)| n as usize)
            .expect("λ on the curve");
        config = config
            .early_decode(true)
            .recv_timeout(Duration::from_secs(10))
            .chaos(ChaosPlan::kill_k_workers_after_exchange(0xC0FFEE, n, kills).into_shared());
    }
    Arc::new(
        Deployment::provision(
            SchemeSpec::Age { lambda: Some(lambda) },
            SchemeParams::new(2, 2, 2),
            config.build(),
        )
        .expect("autoscale provision"),
    )
}

/// Drive `k` seeded jobs, pinning every output against the plaintext
/// product; returns how many dropped (failed or diverged).
fn autoscale_jobs(dep: &Deployment, a: &FpMat, b: &FpMat, y: &FpMat, base: u64, k: u64) -> u64 {
    let mut dropped = 0;
    for i in 0..k {
        match dep.execute_seeded(a, b, base + i) {
            Ok(out) if out.y == *y => {}
            _ => dropped += 1,
        }
    }
    dropped
}

fn autoscale_wait_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    while dep.health().respawns < want {
        dep.runtime().reap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "autoscale: respawns stuck at {}",
            dep.health().respawns
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// One static `(scheme, λ)` point through the profile's 8-job stream.
fn run_autoscale_static(
    lambda: usize,
    kills: usize,
    a: &FpMat,
    b: &FpMat,
    y: &FpMat,
) -> AutoscaleStaticCase {
    let dep = autoscale_provision(lambda, kills);
    let mut dropped = autoscale_jobs(&dep, a, b, y, 0x9000, 1);
    if kills > 0 {
        autoscale_wait_respawns(&dep, kills as u64);
    }
    dropped += autoscale_jobs(&dep, a, b, y, 0x9100, 7);
    let tel = dep.telemetry();
    let jobs = tel.jobs_completed;
    AutoscaleStaticCase {
        spec: dep.scheme().name(),
        lambda: lambda as u64,
        n_workers: dep.n_workers(),
        jobs,
        dropped_jobs: dropped,
        w2w_scalars_per_job: tel.w2w_scalars / jobs.max(1),
        mean_e2e_ns: tel.latency_ns_total / jobs.max(1),
        recovery_margin: dep.n_workers() as i64 - AUTOSCALE_QUOTA - kills as i64,
    }
}

/// Adaptive vs static under one mis-provisioning profile: sweep every
/// static λ through the deterministic job stream, then run the same
/// stream on a controller-steered deployment that starts at
/// `start_lambda`. The controller must converge onto the static sweep's
/// winner with zero dropped jobs — asserted here so a policy regression
/// fails the bench, not just a JSON diff.
fn run_autoscale(
    profile: &str,
    start_lambda: usize,
    kills: usize,
    static_lambdas: &[usize],
) -> AutoscaleCase {
    let mut rng = ChaChaRng::seed_from_u64(0xA5CA1E);
    let a = FpMat::random(&mut rng, AUTOSCALE_M, AUTOSCALE_M);
    let b = FpMat::random(&mut rng, AUTOSCALE_M, AUTOSCALE_M);
    let y = a.transpose().matmul(&b);

    let statics: Vec<AutoscaleStaticCase> = static_lambdas
        .iter()
        .map(|&l| run_autoscale_static(l, kills, &a, &b, &y))
        .collect();
    // The profile's objective: healthy links minimize the measured ζ
    // traffic (fewest Phase-2 scalars, then fewest workers); a
    // straggler-degraded fleet maximizes surviving standby margin among
    // the configs that dropped nothing.
    let best = if kills == 0 {
        statics
            .iter()
            .min_by_key(|c| (c.w2w_scalars_per_job, c.n_workers))
            .expect("non-empty static sweep")
    } else {
        statics
            .iter()
            .filter(|c| c.dropped_jobs == 0)
            .max_by_key(|c| c.recovery_margin)
            .expect("a static config that survives the kills")
    };
    let best_static_spec = best.spec.clone();

    // Adaptive: same stream, controller attached, deliberately
    // mis-provisioned start. 4 jobs fill the policy's minimum window;
    // one manual tick must land the swap; 4 more jobs run on green.
    let dep = autoscale_provision(start_lambda, kills);
    let start_spec = dep.scheme().name();
    let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());
    let mut dropped = autoscale_jobs(&dep, &a, &b, &y, 0xA000, 1);
    if kills > 0 {
        autoscale_wait_respawns(&dep, kills as u64);
    }
    dropped += autoscale_jobs(&dep, &a, &b, &y, 0xA100, 3);
    match scaler.tick() {
        Decision::Reconfigure(rec) => {
            println!(
                "bench perf_core/autoscale profile={profile}  swap cause={:?} \
                 predicted_gain={:.1}%",
                rec.cause, rec.predicted_gain_pct
            );
        }
        other => panic!("{profile}: controller held instead of reconfiguring: {other:?}"),
    }
    dropped += autoscale_jobs(&dep, &a, &b, &y, 0xA200, 4);

    let health = scaler.health();
    let tel = dep.telemetry();
    let jobs = tel.jobs_completed;
    let converged_spec = dep.scheme().name();
    assert_eq!(dropped, 0, "{profile}: the blue/green swap dropped jobs");
    assert_eq!(
        converged_spec, best_static_spec,
        "{profile}: adaptive converged off the static sweep's winner"
    );
    let case = AutoscaleCase {
        profile: profile.to_string(),
        start_spec,
        converged_spec,
        best_static_spec,
        reconfigurations: health.reconfigurations,
        jobs,
        dropped_jobs: dropped,
        converged_matches_best: true,
        adaptive_w2w_scalars_per_job: tel.w2w_scalars / jobs.max(1),
        adaptive_mean_e2e_ns: tel.latency_ns_total / jobs.max(1),
        statics,
    };
    println!(
        "bench perf_core/autoscale profile={profile}  start={} converged={} \
         best_static={} reconfigs={} jobs={} dropped={}",
        case.start_spec,
        case.converged_spec,
        case.best_static_spec,
        case.reconfigurations,
        case.jobs,
        case.dropped_jobs,
    );
    case
}

fn run_shape(s: usize, t: usize, z: usize, m: usize, iters: usize, cases: &mut Vec<Case>) {
    let params = SchemeParams::new(s, t, z);
    let mut rng = ChaChaRng::seed_from_u64(0xB2);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let mut base_e2e: Option<u64> = None;
    for &threads in &THREAD_SWEEP {
        let config = ProtocolConfig::builder().threads(threads).build();
        let dep = Deployment::provision(SchemeSpec::Age { lambda: None }, params, config)
            .expect("provision");
        // Latency: best-of-iters end-to-end (verify on — includes the
        // parallel reference product) plus the matching phase splits.
        let mut best_e2e = u64::MAX;
        let (mut enc, mut comp, mut dec) = (0u64, 0u64, 0u64);
        for i in 0..iters {
            let t0 = Instant::now();
            let out = dep.execute_seeded(&a, &b, 7 + i as u64).expect("execute");
            let e2e = ns(t0.elapsed());
            assert!(out.verified);
            if e2e < best_e2e {
                best_e2e = e2e;
                enc = ns(out.timings.phase1_share);
                comp = ns(out.timings.phase2_compute);
                dec = ns(out.timings.phase3_reconstruct);
            }
        }
        // Throughput: a drain of 8 queued jobs on a same-sized coordinator
        // (verify off — steady-state serving throughput). One warmup job is
        // drained first so the O(N³) setup solve and backend provisioning
        // happen outside the timed window.
        let mut coord = Coordinator::new(
            CoordinatorConfig::builder()
                .policy(SchemePolicy::Fixed(SchemeSpec::Age { lambda: None }))
                .verify(false)
                .threads(threads)
                .build(),
        );
        coord.submit(a.clone(), b.clone(), s, t, z).expect("warmup submit");
        assert!(coord.drain().iter().all(|r| r.outcome.is_ok()));
        let batch = 8usize;
        for _ in 0..batch {
            coord.submit(a.clone(), b.clone(), s, t, z).expect("submit");
        }
        let t0 = Instant::now();
        let reports = coord.drain();
        let drain_d = t0.elapsed();
        assert!(reports.iter().all(|r| r.outcome.is_ok()));
        let jobs_per_sec = per_second(batch as u64, drain_d);

        let baseline = *base_e2e.get_or_insert(best_e2e);
        let speedup = baseline as f64 / best_e2e.max(1) as f64;
        println!(
            "bench perf_core/{} m={m} threads={threads}       e2e={:>10}ns encode={enc}ns \
             speedup_vs_1t={speedup:.2} drain={jobs_per_sec:.1} jobs/s",
            dep.scheme().name(),
            best_e2e,
        );
        cases.push(Case {
            scheme: dep.scheme().name(),
            s,
            t,
            z,
            m,
            threads,
            iters,
            encode_ns: enc,
            compute_ns: comp,
            decode_ns: dec,
            e2e_ns: best_e2e,
            jobs_per_sec,
            speedup_e2e_vs_1t: speedup,
            peak_rss_bytes: peak_rss_bytes(),
        });
    }
}

fn main() {
    let mut smoke = false;
    let mut out_path = String::from("../BENCH_10.json");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--smoke" => smoke = true,
            "--out" => out_path = args.next().expect("--out needs a path"),
            // cargo appends `--bench` to bench-binary invocations even with
            // `harness = false`; swallow it like criterion does.
            "--bench" => {}
            other => panic!("unknown perf_core arg: {other}"),
        }
    }
    let iters = if smoke { 1 } else { 5 };
    let shapes: &[(usize, usize, usize, usize)] = if smoke {
        &[(2, 2, 2, 32)]
    } else {
        &[(2, 2, 2, 64), (2, 2, 2, 128), (3, 2, 2, 96)]
    };

    let mut cases = Vec::new();
    for &(s, t, z, m) in shapes {
        run_shape(s, t, z, m, iters, &mut cases);
    }
    let churn_jobs = if smoke { 4 } else { 64 };
    let churn_shapes: &[usize] = if smoke { &[16] } else { &[16, 32] };
    let churn: Vec<ChurnCase> = churn_shapes
        .iter()
        .map(|&m| run_churn(2, 2, 2, m, churn_jobs))
        .collect();
    let (fault_delay, fault_iters, fault_m) = if smoke {
        (Duration::from_millis(15), 2, 16)
    } else {
        (Duration::from_millis(40), 3, 64)
    };
    let fault: Vec<FaultCase> = [0usize, 1, 2]
        .iter()
        .map(|&k| run_fault(2, 2, 2, fault_m, k, fault_delay, fault_iters))
        .collect();
    // Wire section: m must keep the G-block ≥ ~200 scalars so the fixed
    // per-frame header stays under the 5% framing budget.
    let wire_m = if smoke { 32 } else { 64 };
    let wire: Vec<WireCase> = ["age", "polydot", "entangled"]
        .iter()
        .map(|&scheme| run_wire(scheme, 2, 2, 2, wire_m))
        .collect();
    let gateway: Vec<GatewayCase> = if smoke {
        vec![run_gateway(2, 4, 16)]
    } else {
        vec![run_gateway(2, 16, 32), run_gateway(4, 16, 32)]
    };
    let (byz_m, byz_iters) = if smoke { (16, 2) } else { (64, 3) };
    let mut byzantine: Vec<ByzantineCase> = Vec::new();
    for adv in [0usize, 1, 2] {
        let baseline = byzantine.first().map(|c| c.e2e_ns);
        byzantine.push(run_byzantine(adv, byz_m, byz_iters, baseline));
    }
    // Fused batching: every scheme at batch 1/4/16 — the serving profile
    // the kernel fusion targets (small m, high job rate).
    let fused_m = if smoke { 16 } else { 32 };
    let mut fused: Vec<FusedCase> = Vec::new();
    for (spec, label) in [
        (SchemeSpec::Age { lambda: None }, "age"),
        (SchemeSpec::PolyDot, "polydot"),
        (SchemeSpec::Entangled, "entangled"),
    ] {
        for batch in [1usize, 4, 16] {
            fused.push(run_fused(spec, label, fused_m, batch, iters));
        }
    }
    // Pipeline chains: stages-vs-e2e plus the naive per-stage
    // decode-re-encode alternative.
    let pipeline_specs: &[(&str, usize)] = if smoke {
        &[("matmul,truncate:4,matmul", 16)]
    } else {
        &[
            ("matmul,matmul", 32),
            ("matmul,truncate:8,matmul", 32),
            ("matmul,truncate:3,matmul,scale:5,transpose,matmul", 32),
        ]
    };
    let pipeline: Vec<PipelineCase> = pipeline_specs
        .iter()
        .map(|&(spec, m)| run_pipeline_bench(spec, m, iters))
        .collect();
    // Autoscale: adaptive-vs-static over the two mis-provisioning
    // profiles. Deterministic convergence, not timing — the same sweep
    // runs in smoke and full mode.
    let autoscale: Vec<AutoscaleCase> = vec![
        run_autoscale("bandwidth", 0, 0, &[0, 1, 2]),
        run_autoscale("straggler", 2, 2, &[0, 2]),
    ];
    let gate = run_gate(if smoke { 2 } else { 5 });

    let host_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1) as u64;
    let json = Json::obj(vec![
        ("schema", Json::Str("cmpc.bench.v10".to_string())),
        ("benchmark", Json::Str("perf_core".to_string())),
        ("provenance", Json::Str("measured".to_string())),
        (
            "note",
            Json::Str(
                "regenerate with `cargo bench --bench perf_core` from rust/".to_string(),
            ),
        ),
        ("host_threads", Json::Int(host_threads)),
        (
            "thread_sweep",
            Json::Arr(THREAD_SWEEP.iter().map(|&t| Json::Int(t as u64)).collect()),
        ),
        ("peak_rss_bytes", Json::Int(peak_rss_bytes())),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("scheme", Json::Str(c.scheme.clone())),
                            ("s", Json::Int(c.s as u64)),
                            ("t", Json::Int(c.t as u64)),
                            ("z", Json::Int(c.z as u64)),
                            ("m", Json::Int(c.m as u64)),
                            ("threads", Json::Int(c.threads as u64)),
                            ("iters", Json::Int(c.iters as u64)),
                            ("encode_ns", Json::Int(c.encode_ns)),
                            ("compute_ns", Json::Int(c.compute_ns)),
                            ("decode_ns", Json::Int(c.decode_ns)),
                            ("e2e_ns", Json::Int(c.e2e_ns)),
                            ("jobs_per_sec", Json::Float(c.jobs_per_sec)),
                            ("speedup_e2e_vs_1t", Json::Float(c.speedup_e2e_vs_1t)),
                            ("peak_rss_bytes", Json::Int(c.peak_rss_bytes)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "churn",
            Json::Arr(
                churn
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("m", Json::Int(c.m as u64)),
                            ("jobs", Json::Int(c.jobs as u64)),
                            ("warm_jobs_per_sec", Json::Float(c.warm_jobs_per_sec)),
                            ("cold_jobs_per_sec", Json::Float(c.cold_jobs_per_sec)),
                            (
                                "speedup_warm_vs_cold",
                                Json::Float(c.speedup_warm_vs_cold),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fault",
            Json::Arr(
                fault
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("stragglers", Json::Int(c.stragglers as u64)),
                            ("delay_ms", Json::Int(c.delay_ms)),
                            ("e2e_full_ns", Json::Int(c.e2e_full_ns)),
                            ("e2e_early_ns", Json::Int(c.e2e_early_ns)),
                            ("early_decode_win", Json::Float(c.early_decode_win)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "wire",
            Json::Arr(
                wire.iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("scheme", Json::Str(c.scheme.clone())),
                            ("m", Json::Int(c.m as u64)),
                            ("n_workers", Json::Int(c.n_workers as u64)),
                            ("w2w_wire_bytes", Json::Int(c.w2w_wire_bytes)),
                            ("zeta_bytes", Json::Int(c.zeta_bytes)),
                            ("overhead_pct", Json::Float(c.overhead_pct)),
                            ("total_wire_bytes", Json::Int(c.total_wire_bytes)),
                            ("e2e_ns", Json::Int(c.e2e_ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gateway",
            Json::Arr(
                gateway
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("tenants", Json::Int(c.tenants as u64)),
                            ("jobs_per_tenant", Json::Int(c.jobs_per_tenant as u64)),
                            ("m", Json::Int(c.m as u64)),
                            ("sustained_qps", Json::Float(c.sustained_qps)),
                            ("p50_us", Json::Int(c.p50_us)),
                            ("p99_us", Json::Int(c.p99_us)),
                            ("batches", Json::Int(c.batches)),
                            ("batched_jobs", Json::Int(c.batched_jobs)),
                            ("max_batch", Json::Int(c.max_batch as u64)),
                            (
                                "batch_size_hist",
                                Json::Arr(
                                    c.batch_size_hist.iter().map(|&v| Json::Int(v)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "byzantine",
            Json::Arr(
                byzantine
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            (
                                "adversary_tolerance",
                                Json::Int(c.adversary_tolerance as u64),
                            ),
                            ("m", Json::Int(c.m as u64)),
                            ("e2e_ns", Json::Int(c.e2e_ns)),
                            ("decode_ns", Json::Int(c.decode_ns)),
                            ("overhead_vs_a0", Json::Float(c.overhead_vs_a0)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fused",
            Json::Arr(
                fused
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("scheme", Json::Str(c.scheme.clone())),
                            ("m", Json::Int(c.m as u64)),
                            ("batch", Json::Int(c.batch as u64)),
                            ("fused_ns", Json::Int(c.fused_ns)),
                            ("sequential_ns", Json::Int(c.sequential_ns)),
                            (
                                "speedup_fused_vs_seq",
                                Json::Float(c.speedup_fused_vs_seq),
                            ),
                            ("fused_jobs_per_sec", Json::Float(c.fused_jobs_per_sec)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "pipeline",
            Json::Arr(
                pipeline
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("spec", Json::Str(c.spec.clone())),
                            ("m", Json::Int(c.m as u64)),
                            ("rounds", Json::Int(c.rounds as u64)),
                            (
                                "stage_ns",
                                Json::Arr(c.stage_ns.iter().map(|&v| Json::Int(v)).collect()),
                            ),
                            ("stages_sum_ns", Json::Int(c.stages_sum_ns)),
                            ("e2e_ns", Json::Int(c.e2e_ns)),
                            ("naive_ns", Json::Int(c.naive_ns)),
                            ("speedup_vs_naive", Json::Float(c.speedup_vs_naive)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "autoscale",
            Json::Arr(
                autoscale
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("profile", Json::Str(c.profile.clone())),
                            ("start_spec", Json::Str(c.start_spec.clone())),
                            ("converged_spec", Json::Str(c.converged_spec.clone())),
                            ("best_static_spec", Json::Str(c.best_static_spec.clone())),
                            ("reconfigurations", Json::Int(c.reconfigurations)),
                            ("jobs", Json::Int(c.jobs)),
                            ("dropped_jobs", Json::Int(c.dropped_jobs)),
                            (
                                "converged_matches_best",
                                Json::Bool(c.converged_matches_best),
                            ),
                            (
                                "adaptive_w2w_scalars_per_job",
                                Json::Int(c.adaptive_w2w_scalars_per_job),
                            ),
                            ("adaptive_mean_e2e_ns", Json::Int(c.adaptive_mean_e2e_ns)),
                            (
                                "statics",
                                Json::Arr(
                                    c.statics
                                        .iter()
                                        .map(|s| {
                                            Json::obj(vec![
                                                ("spec", Json::Str(s.spec.clone())),
                                                ("lambda", Json::Int(s.lambda)),
                                                ("n_workers", Json::Int(s.n_workers as u64)),
                                                ("jobs", Json::Int(s.jobs)),
                                                ("dropped_jobs", Json::Int(s.dropped_jobs)),
                                                (
                                                    "w2w_scalars_per_job",
                                                    Json::Int(s.w2w_scalars_per_job),
                                                ),
                                                ("mean_e2e_ns", Json::Int(s.mean_e2e_ns)),
                                                (
                                                    "recovery_margin",
                                                    Json::Int(s.recovery_margin.max(0) as u64),
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gate",
            Json::obj(vec![
                ("m", Json::Int(gate.m as u64)),
                ("threads", Json::Int(gate.threads as u64)),
                ("e2e_ns", Json::Int(gate.e2e_ns)),
                ("calib_ns", Json::Int(gate.calib_ns)),
                ("e2e_per_calib", Json::Float(gate.e2e_per_calib)),
            ]),
        ),
    ]);
    let rendered = format!("{}\n", json.render());
    std::fs::write(&out_path, &rendered).expect("write BENCH json");
    println!("perf_core: wrote {} cases to {out_path}", cases.len());
}
