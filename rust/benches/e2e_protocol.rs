//! Bench E9 — end-to-end protocol wall time and serving throughput:
//! AGE vs PolyDot vs Entangled at identical (m, s, t, z), native backend.
//!
//! The headline system effect: fewer workers ⇒ less O(N²) share exchange
//! ⇒ lower job latency at equal privacy.

use cmpc::benchkit::{bench, per_second};
use cmpc::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::{prepare_setup, run_protocol_with_setup, ProtocolConfig};
use cmpc::util::rng::ChaChaRng;

fn main() {
    let (s, t, z) = (2usize, 2usize, 2usize);
    let m = 128;
    let mut rng = ChaChaRng::seed_from_u64(1);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let cfg = ProtocolConfig::builder().verify(false).build();

    let schemes: Vec<Box<dyn CmpcScheme>> = vec![
        Box::new(AgeCmpc::with_optimal_lambda(s, t, z)),
        Box::new(PolyDotCmpc::new(s, t, z)),
        Box::new(EntangledCmpc::new(s, t, z)),
    ];
    for scheme in &schemes {
        let setup = prepare_setup(scheme.as_ref()).unwrap();
        let name = format!(
            "e2e/{} m={m} N={}",
            scheme.name(),
            scheme.n_workers()
        );
        bench(&name, 1, 10, || {
            run_protocol_with_setup(scheme.as_ref(), &setup, &a, &b, &cfg).unwrap();
        });
    }

    // Coordinator throughput with deployment caching (batch of 8 jobs).
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .policy(SchemePolicy::Adaptive)
            .verify(false)
            .build(),
    );
    let jobs = 8;
    let t0 = std::time::Instant::now();
    for _ in 0..jobs {
        coord.submit(a.clone(), b.clone(), s, t, z).unwrap();
    }
    let reports = coord.drain();
    let d = t0.elapsed();
    assert!(reports.iter().all(|r| r.outcome.is_ok()));
    let hits = reports.iter().filter(|r| r.setup_cache_hit).count();
    println!(
        "bench e2e/coordinator m={m} jobs={jobs}            throughput={:.2} jobs/s cache_hits={hits}/{jobs}",
        per_second(jobs as u64, d)
    );
}
