//! Bench E3/E4/E5 — Fig. 4(a–c) regeneration: computation, storage and
//! communication load per worker vs s/t (m=36000, st=36, z=42).

use cmpc::analysis::figures::fig4_overheads;
use cmpc::benchkit::bench;

fn main() {
    let mut rows = Vec::new();
    bench("fig4/overheads m=36000 st=36 z=42", 1, 10, || {
        rows = fig4_overheads(36000, 36, 42);
    });
    for (label, idx) in [("computation (mults)", 2usize), ("storage (B)", 3), ("communication (B)", 4)] {
        println!("\nFig4 {label}:");
        println!("(s,t)      AGE          PolyDot      Entangled    SSMM         GCSA-NA");
        for r in &rows {
            let v = |i: usize| -> f64 {
                match idx {
                    2 => r.per_scheme[i].2 as f64,
                    3 => r.per_scheme[i].3 as f64,
                    _ => r.per_scheme[i].4 as f64,
                }
            };
            println!(
                "({:>2},{:>2})  {:>11.4e}  {:>11.4e}  {:>11.4e}  {:>11.4e}  {:>11.4e}",
                r.s, r.t, v(0), v(1), v(2), v(3), v(4)
            );
        }
    }
    // Shape assertions matching §VII's reading of the figure: AGE minimal in
    // every column; computation non-monotonic with interior minimum.
    for r in &rows {
        for i in 1..r.per_scheme.len() {
            assert!(r.per_scheme[0].2 <= r.per_scheme[i].2);
            assert!(r.per_scheme[0].3 <= r.per_scheme[i].3);
            assert!(r.per_scheme[0].4 <= r.per_scheme[i].4);
        }
    }
    let comp: Vec<u128> = rows.iter().map(|r| r.per_scheme[0].2).collect();
    let min_idx = comp.iter().enumerate().min_by_key(|&(_, v)| v).unwrap().0;
    assert!(min_idx > 0 && min_idx + 1 < comp.len());
    println!(
        "\ncomputation minimum at (s,t)=({},{}) — interior, as in Fig. 4(a)",
        rows[min_idx].s, rows[min_idx].t
    );
}
